//! RV32IM(+Zicsr) instruction-set definitions, decoder, encoder, and a
//! two-pass assembler.
//!
//! This is the ISA substrate for the emulated X-HEEP host CPU (paper §IV-A
//! picks X-HEEP, whose cores are RV32 — we model an RV32IM machine-mode
//! core). Guest programs — the case-study kernels and acquisition loops in
//! [`crate::workloads`] — are written in assembly, assembled by [`asm`],
//! and executed by [`crate::cpu`].
//!
//! The decoder and encoder are exact inverses over the supported subset;
//! this is property-tested in `rust/tests/prop_isa.rs`.

pub mod asm;
pub mod decode;
pub mod disasm;
pub mod encode;

pub use asm::{assemble, assemble_with, Program};
pub use decode::decode;
pub use disasm::{disassemble, disassemble_word, listing};
pub use encode::encode;

/// Architectural register index (x0..x31).
pub type Reg = u8;

/// ABI register names, indexed by register number (for disassembly and
/// assembler diagnostics).
pub const ABI_NAMES: [&str; 32] = [
    "zero", "ra", "sp", "gp", "tp", "t0", "t1", "t2", "s0", "s1", "a0", "a1", "a2", "a3", "a4",
    "a5", "a6", "a7", "s2", "s3", "s4", "s5", "s6", "s7", "s8", "s9", "s10", "s11", "t3", "t4",
    "t5", "t6",
];

/// ALU operation, shared by the register-register and (where legal)
/// immediate forms, plus the M extension.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AluOp {
    Add,
    Sub,
    Sll,
    Slt,
    Sltu,
    Xor,
    Srl,
    Sra,
    Or,
    And,
    // M extension
    Mul,
    Mulh,
    Mulhsu,
    Mulhu,
    Div,
    Divu,
    Rem,
    Remu,
}

impl AluOp {
    /// True for the M-extension ops (they live under funct7=0000001).
    pub fn is_m(self) -> bool {
        matches!(
            self,
            AluOp::Mul
                | AluOp::Mulh
                | AluOp::Mulhsu
                | AluOp::Mulhu
                | AluOp::Div
                | AluOp::Divu
                | AluOp::Rem
                | AluOp::Remu
        )
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BranchOp {
    Eq,
    Ne,
    Lt,
    Ge,
    Ltu,
    Geu,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LoadOp {
    Lb,
    Lh,
    Lw,
    Lbu,
    Lhu,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StoreOp {
    Sb,
    Sh,
    Sw,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CsrOp {
    Rw,
    Rs,
    Rc,
}

/// A decoded RV32IM instruction.
///
/// Immediates are stored sign-extended ready for use; shift-immediates are
/// kept in `imm` (low 5 bits significant).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Instr {
    Lui { rd: Reg, imm: i32 },
    Auipc { rd: Reg, imm: i32 },
    Jal { rd: Reg, imm: i32 },
    Jalr { rd: Reg, rs1: Reg, imm: i32 },
    Branch { op: BranchOp, rs1: Reg, rs2: Reg, imm: i32 },
    Load { op: LoadOp, rd: Reg, rs1: Reg, imm: i32 },
    Store { op: StoreOp, rs1: Reg, rs2: Reg, imm: i32 },
    /// Register-immediate ALU op. Only Add/Slt/Sltu/Xor/Or/And/Sll/Srl/Sra
    /// are legal here; the decoder never produces others.
    OpImm { op: AluOp, rd: Reg, rs1: Reg, imm: i32 },
    /// Register-register ALU op (including the M extension).
    Op { op: AluOp, rd: Reg, rs1: Reg, rs2: Reg },
    Fence,
    Ecall,
    Ebreak,
    /// Wait-for-interrupt: the core clock-gates until an interrupt is
    /// pending (paper §IV-C power states).
    Wfi,
    Mret,
    /// CSR access; `imm=true` means the rs1 field is a 5-bit zimm.
    Csr { op: CsrOp, rd: Reg, rs1: Reg, csr: u16, imm: bool },
}

/// CSR addresses implemented by the core (machine mode only, plus the
/// counters the perf-monitor flow reads).
pub mod csr {
    pub const MSTATUS: u16 = 0x300;
    pub const MIE: u16 = 0x304;
    pub const MTVEC: u16 = 0x305;
    pub const MSCRATCH: u16 = 0x340;
    pub const MEPC: u16 = 0x341;
    pub const MCAUSE: u16 = 0x342;
    pub const MTVAL: u16 = 0x343;
    pub const MIP: u16 = 0x344;
    pub const MCYCLE: u16 = 0xB00;
    pub const MINSTRET: u16 = 0xB02;
    pub const MCYCLEH: u16 = 0xB80;
    pub const MINSTRETH: u16 = 0xB82;
    pub const MHARTID: u16 = 0xF14;
}

/// Parse a register name: `x0..x31` or an ABI name.
pub fn parse_reg(s: &str) -> Option<Reg> {
    if let Some(rest) = s.strip_prefix('x') {
        if let Ok(n) = rest.parse::<u8>() {
            if n < 32 {
                return Some(n);
            }
        }
    }
    ABI_NAMES.iter().position(|&n| n == s).map(|i| i as Reg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_reg_accepts_both_names() {
        assert_eq!(parse_reg("x0"), Some(0));
        assert_eq!(parse_reg("zero"), Some(0));
        assert_eq!(parse_reg("a0"), Some(10));
        assert_eq!(parse_reg("x31"), Some(31));
        assert_eq!(parse_reg("t6"), Some(31));
        assert_eq!(parse_reg("x32"), None);
        assert_eq!(parse_reg("q3"), None);
    }

    #[test]
    fn m_ops_classified() {
        assert!(AluOp::Mul.is_m());
        assert!(AluOp::Remu.is_m());
        assert!(!AluOp::Add.is_m());
        assert!(!AluOp::Sra.is_m());
    }
}
