//! RV32IM disassembler.
//!
//! Renders decoded instructions in the same syntax [`super::asm`]
//! accepts, so `assemble(disassemble(words)) == words` — the round-trip
//! property `rust/tests/prop_isa.rs` checks. Used by the debugger
//! virtualization (`disasm` protocol command, trace rendering).

use super::{AluOp, BranchOp, CsrOp, Instr, LoadOp, StoreOp, ABI_NAMES};

fn reg(i: u8) -> &'static str {
    ABI_NAMES[i as usize]
}

fn csr_name(addr: u16) -> String {
    use super::csr::*;
    match addr {
        MSTATUS => "mstatus".into(),
        MIE => "mie".into(),
        MTVEC => "mtvec".into(),
        MSCRATCH => "mscratch".into(),
        MEPC => "mepc".into(),
        MCAUSE => "mcause".into(),
        MTVAL => "mtval".into(),
        MIP => "mip".into(),
        MCYCLE => "mcycle".into(),
        MINSTRET => "minstret".into(),
        MCYCLEH => "mcycleh".into(),
        MINSTRETH => "minstreth".into(),
        MHARTID => "mhartid".into(),
        other => format!("{other:#x}"),
    }
}

/// Render one instruction. Branch/jump targets are shown as absolute
/// addresses computed from `pc` (assembler-compatible numeric targets).
pub fn disassemble(instr: Instr, pc: u32) -> String {
    match instr {
        Instr::Lui { rd, imm } => format!("lui {}, {:#x}", reg(rd), (imm as u32) >> 12),
        Instr::Auipc { rd, imm } => format!("auipc {}, {:#x}", reg(rd), (imm as u32) >> 12),
        Instr::Jal { rd, imm } => {
            let target = pc.wrapping_add(imm as u32);
            if rd == 0 {
                format!("j {target:#x}")
            } else if rd == 1 {
                format!("jal {target:#x}")
            } else {
                format!("jal {}, {target:#x}", reg(rd))
            }
        }
        Instr::Jalr { rd, rs1, imm } => {
            if rd == 0 && imm == 0 && rs1 == 1 {
                "ret".into()
            } else if rd == 0 && imm == 0 {
                format!("jr {}", reg(rs1))
            } else {
                format!("jalr {}, {}, {}", reg(rd), reg(rs1), imm)
            }
        }
        Instr::Branch { op, rs1, rs2, imm } => {
            let target = pc.wrapping_add(imm as u32);
            let name = match op {
                BranchOp::Eq => "beq",
                BranchOp::Ne => "bne",
                BranchOp::Lt => "blt",
                BranchOp::Ge => "bge",
                BranchOp::Ltu => "bltu",
                BranchOp::Geu => "bgeu",
            };
            format!("{name} {}, {}, {target:#x}", reg(rs1), reg(rs2))
        }
        Instr::Load { op, rd, rs1, imm } => {
            let name = match op {
                LoadOp::Lb => "lb",
                LoadOp::Lh => "lh",
                LoadOp::Lw => "lw",
                LoadOp::Lbu => "lbu",
                LoadOp::Lhu => "lhu",
            };
            format!("{name} {}, {}({})", reg(rd), imm, reg(rs1))
        }
        Instr::Store { op, rs1, rs2, imm } => {
            let name = match op {
                StoreOp::Sb => "sb",
                StoreOp::Sh => "sh",
                StoreOp::Sw => "sw",
            };
            format!("{name} {}, {}({})", reg(rs2), imm, reg(rs1))
        }
        Instr::OpImm { op, rd, rs1, imm } => {
            if op == AluOp::Add && imm == 0 {
                if rd == 0 && rs1 == 0 {
                    return "nop".into();
                }
                return format!("mv {}, {}", reg(rd), reg(rs1));
            }
            if op == AluOp::Add && rs1 == 0 {
                return format!("li {}, {}", reg(rd), imm);
            }
            let name = match op {
                AluOp::Add => "addi",
                AluOp::Slt => "slti",
                AluOp::Sltu => "sltiu",
                AluOp::Xor => "xori",
                AluOp::Or => "ori",
                AluOp::And => "andi",
                AluOp::Sll => "slli",
                AluOp::Srl => "srli",
                AluOp::Sra => "srai",
                other => unreachable!("no immediate form for {other:?}"),
            };
            format!("{name} {}, {}, {}", reg(rd), reg(rs1), imm)
        }
        Instr::Op { op, rd, rs1, rs2 } => {
            let name = match op {
                AluOp::Add => "add",
                AluOp::Sub => "sub",
                AluOp::Sll => "sll",
                AluOp::Slt => "slt",
                AluOp::Sltu => "sltu",
                AluOp::Xor => "xor",
                AluOp::Srl => "srl",
                AluOp::Sra => "sra",
                AluOp::Or => "or",
                AluOp::And => "and",
                AluOp::Mul => "mul",
                AluOp::Mulh => "mulh",
                AluOp::Mulhsu => "mulhsu",
                AluOp::Mulhu => "mulhu",
                AluOp::Div => "div",
                AluOp::Divu => "divu",
                AluOp::Rem => "rem",
                AluOp::Remu => "remu",
            };
            format!("{name} {}, {}, {}", reg(rd), reg(rs1), reg(rs2))
        }
        Instr::Fence => "fence".into(),
        Instr::Ecall => "ecall".into(),
        Instr::Ebreak => "ebreak".into(),
        Instr::Wfi => "wfi".into(),
        Instr::Mret => "mret".into(),
        Instr::Csr { op, rd, rs1, csr, imm } => {
            let base = match (op, imm) {
                (CsrOp::Rw, false) => "csrrw",
                (CsrOp::Rs, false) => "csrrs",
                (CsrOp::Rc, false) => "csrrc",
                (CsrOp::Rw, true) => "csrrwi",
                (CsrOp::Rs, true) => "csrrsi",
                (CsrOp::Rc, true) => "csrrci",
            };
            if imm {
                format!("{base} {}, {}, {}", reg(rd), csr_name(csr), rs1)
            } else {
                format!("{base} {}, {}, {}", reg(rd), csr_name(csr), reg(rs1))
            }
        }
    }
}

/// Disassemble a word, or render a raw `.word` for undecodable data.
pub fn disassemble_word(word: u32, pc: u32) -> String {
    match super::decode(word) {
        Some(i) => disassemble(i, pc),
        None => format!(".word {word:#010x}"),
    }
}

/// A listing of `words` starting at `base`: `addr: word  text` lines.
pub fn listing(words: &[u32], base: u32) -> String {
    let mut out = String::new();
    for (i, &w) in words.iter().enumerate() {
        let pc = base + (i * 4) as u32;
        out.push_str(&format!("{pc:#010x}: {w:08x}  {}\n", disassemble_word(w, pc)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::{assemble, decode};
    use super::*;

    #[test]
    fn known_forms() {
        let check = |src: &str, want: &str| {
            let p = assemble(src).unwrap();
            let got = disassemble(decode(p.text[0]).unwrap(), 0);
            assert_eq!(got, want, "{src}");
        };
        check("addi a0, zero, 42", "li a0, 42");
        check("mv a1, a0", "mv a1, a0");
        check("nop", "nop");
        check("mul s2, s3, s4", "mul s2, s3, s4");
        check("lw t0, -4(sp)", "lw t0, -4(sp)");
        check("sw t0, 8(sp)", "sw t0, 8(sp)");
        check("ret", "ret");
        check("wfi", "wfi");
        check("csrr t0, mcycle", "csrrs t0, mcycle, zero");
        check("srai a2, a3, 7", "srai a2, a3, 7");
    }

    #[test]
    fn branch_targets_absolute() {
        let p = assemble("_start:\nbeq a0, a1, _start").unwrap();
        assert_eq!(disassemble(decode(p.text[0]).unwrap(), 0), "beq a0, a1, 0x0");
        // at non-zero pc the target shifts accordingly
        assert_eq!(disassemble(decode(p.text[0]).unwrap(), 0x100), "beq a0, a1, 0x100");
    }

    #[test]
    fn undecodable_word_renders_as_data() {
        assert_eq!(disassemble_word(0, 0), ".word 0x00000000");
    }

    #[test]
    fn listing_format() {
        let p = assemble("li a0, 1\nebreak").unwrap();
        let l = listing(&p.text, 0);
        assert!(l.contains("0x00000000:"));
        assert!(l.contains("li a0, 1"));
        assert!(l.contains("ebreak"));
        assert_eq!(l.lines().count(), 2);
    }
}
