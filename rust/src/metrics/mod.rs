//! Lock-cheap control-plane metrics (DESIGN.md §14).
//!
//! Primitives for instrumenting the server's hot paths without
//! contending on them: [`Counter`]/[`Gauge`] are single relaxed
//! atomics, and [`Histogram`] is a fixed-bucket array of atomics (no
//! allocation, no lock) with percentile estimates read from the bucket
//! upper bounds. Cross-thread reads are monitoring-grade: each cell is
//! individually consistent, snapshots across cells are not serialized
//! — exactly the Prometheus contract.
//!
//! [`ServerMetrics`] aggregates what the control server records:
//! per-command call/error/latency stats, batch sizes, trace-stream
//! backpressure, and byte/connection totals. Session and worker-pool
//! counters live with their owners ([`crate::server::session`],
//! [`crate::coordinator::fleet`]) and are joined into the `metrics`
//! protocol response (proto v6) by the server.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Up/down instantaneous value (queue depths, open connections).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn new() -> Self {
        Self(AtomicI64::new(0))
    }

    #[inline]
    pub fn add(&self, delta: i64) {
        self.0.fetch_add(delta, Ordering::Relaxed);
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Latency bucket upper bounds in microseconds: 50 µs to 30 s.
pub const LATENCY_BOUNDS_US: &[u64] = &[
    50,
    100,
    250,
    500,
    1_000,
    2_500,
    5_000,
    10_000,
    25_000,
    50_000,
    100_000,
    250_000,
    500_000,
    1_000_000,
    5_000_000,
    30_000_000,
];

/// Size bucket upper bounds (batch lengths, queue depths): powers of 2.
pub const SIZE_BOUNDS: &[u64] = &[1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024];

/// Fixed-bucket histogram: one atomic per bucket plus an overflow
/// bucket, a sum, and a count. Percentiles report the upper bound of
/// the bucket holding the requested rank (the classic fixed-bucket
/// estimate: exact rank selection, value rounded up to a bound).
#[derive(Debug)]
pub struct Histogram {
    bounds: &'static [u64],
    /// `bounds.len() + 1` cells; the last is the overflow bucket.
    counts: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl Histogram {
    pub fn new(bounds: &'static [u64]) -> Self {
        Self {
            bounds,
            counts: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        let idx = self.bounds.partition_point(|&b| b < v);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    /// Value at quantile `p` in `[0, 1]`: the upper bound of the bucket
    /// containing the ceil(p·count)-th sample (overflow samples report
    /// the last finite bound). 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((p.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c.load(Ordering::Relaxed);
            if cum >= rank {
                return self.bounds.get(i).copied().unwrap_or_else(|| {
                    self.bounds.last().copied().unwrap_or(0)
                });
            }
        }
        self.bounds.last().copied().unwrap_or(0)
    }

    /// `{count, sum, mean, p50, p90, p99}` — the JSON shape every
    /// latency/size field in the `metrics` response uses.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("sum", Json::Num(self.sum() as f64)),
            ("mean", Json::Num(self.mean())),
            ("p50", Json::Num(self.percentile(0.50) as f64)),
            ("p90", Json::Num(self.percentile(0.90) as f64)),
            ("p99", Json::Num(self.percentile(0.99) as f64)),
        ])
    }
}

/// Per-command slice of the server metrics.
#[derive(Debug)]
pub struct CommandStats {
    pub calls: Counter,
    pub errors: Counter,
    pub latency_us: Histogram,
}

impl CommandStats {
    fn new() -> Self {
        Self {
            calls: Counter::new(),
            errors: Counter::new(),
            latency_us: Histogram::new(LATENCY_BOUNDS_US),
        }
    }
}

/// Everything the control server records directly. One instance per
/// server, shared across connection threads; every record path is a
/// handful of relaxed atomic ops (the per-command map takes a short
/// lock only to clone out an `Arc`).
#[derive(Debug)]
pub struct ServerMetrics {
    pub connections_opened: Counter,
    pub connections_closed: Counter,
    pub bytes_in: Counter,
    pub bytes_out: Counter,
    pub commands: Counter,
    pub errors: Counter,
    /// All-command latency.
    pub latency_us: Histogram,
    /// `batch` request sizes.
    pub batch_len: Histogram,
    /// Trace-stream backpressure: events delivered vs overwritten
    /// before the subscriber drained them.
    pub trace_events_read: Counter,
    pub trace_events_skipped: Counter,
    per_command: Mutex<BTreeMap<String, Arc<CommandStats>>>,
}

impl Default for ServerMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self {
            connections_opened: Counter::new(),
            connections_closed: Counter::new(),
            bytes_in: Counter::new(),
            bytes_out: Counter::new(),
            commands: Counter::new(),
            errors: Counter::new(),
            latency_us: Histogram::new(LATENCY_BOUNDS_US),
            batch_len: Histogram::new(SIZE_BOUNDS),
            trace_events_read: Counter::new(),
            trace_events_skipped: Counter::new(),
            per_command: Mutex::new(BTreeMap::new()),
        }
    }

    /// The stats cell for one command name (created on first use).
    pub fn command_stats(&self, cmd: &str) -> Arc<CommandStats> {
        let mut map = self.per_command.lock().unwrap_or_else(|p| p.into_inner());
        map.entry(cmd.to_string()).or_insert_with(|| Arc::new(CommandStats::new())).clone()
    }

    /// Record one dispatched command: global and per-command counters
    /// plus latency.
    pub fn observe_command(&self, cmd: &str, ok: bool, micros: u64) {
        self.commands.inc();
        self.latency_us.observe(micros);
        let stats = self.command_stats(cmd);
        stats.calls.inc();
        stats.latency_us.observe(micros);
        if !ok {
            self.errors.inc();
            stats.errors.inc();
        }
    }

    /// Stable-ordered view of the per-command cells.
    pub fn per_command(&self) -> Vec<(String, Arc<CommandStats>)> {
        let map = self.per_command.lock().unwrap_or_else(|p| p.into_inner());
        map.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);

        let g = Gauge::new();
        g.add(3);
        g.add(-1);
        assert_eq!(g.get(), 2);
        g.set(-7);
        assert_eq!(g.get(), -7);
    }

    #[test]
    fn histogram_uniform_distribution_percentiles() {
        let h = Histogram::new(LATENCY_BOUNDS_US);
        for v in 1..=1000u64 {
            h.observe(v);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.sum(), 500_500);
        // uniform 1..=1000 µs against bounds ..,250,500,1000,..: the
        // 500th sample sits in the 500 bucket, the 900th/990th in 1000
        assert_eq!(h.percentile(0.50), 500);
        assert_eq!(h.percentile(0.90), 1_000);
        assert_eq!(h.percentile(0.99), 1_000);
        // rank clamps: p=0 is the first sample's bucket
        assert_eq!(h.percentile(0.0), 50);
    }

    #[test]
    fn histogram_point_mass_and_overflow() {
        let h = Histogram::new(LATENCY_BOUNDS_US);
        for _ in 0..100 {
            h.observe(10);
        }
        // every sample in the first bucket: all percentiles = 50
        assert_eq!(h.percentile(0.5), 50);
        assert_eq!(h.percentile(0.99), 50);

        let o = Histogram::new(LATENCY_BOUNDS_US);
        o.observe(u64::MAX / 2); // way past the last bound
        assert_eq!(o.percentile(0.99), 30_000_000); // clamps to last bound
        assert_eq!(o.count(), 1);
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = Histogram::new(SIZE_BOUNDS);
        assert_eq!(h.percentile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
        let j = h.to_json().to_string();
        assert!(j.contains("\"count\":0"), "{j}");
    }

    #[test]
    fn bimodal_distribution_p50_vs_p99() {
        let h = Histogram::new(LATENCY_BOUNDS_US);
        for _ in 0..95 {
            h.observe(80); // fast mode: bucket 100
        }
        for _ in 0..5 {
            h.observe(40_000); // slow tail: bucket 50_000
        }
        assert_eq!(h.percentile(0.50), 100);
        assert_eq!(h.percentile(0.90), 100);
        assert_eq!(h.percentile(0.99), 50_000);
    }

    #[test]
    fn server_metrics_per_command_accumulates() {
        let m = ServerMetrics::new();
        m.observe_command("ping", true, 120);
        m.observe_command("ping", true, 130);
        m.observe_command("run", false, 9_000);
        assert_eq!(m.commands.get(), 3);
        assert_eq!(m.errors.get(), 1);
        let per = m.per_command();
        assert_eq!(per.len(), 2);
        let ping = &per.iter().find(|(k, _)| k == "ping").unwrap().1;
        assert_eq!(ping.calls.get(), 2);
        assert_eq!(ping.errors.get(), 0);
        assert_eq!(ping.latency_us.count(), 2);
        let run = &per.iter().find(|(k, _)| k == "run").unwrap().1;
        assert_eq!(run.errors.get(), 1);
    }

    #[test]
    fn histogram_is_shareable_across_threads() {
        let h = std::sync::Arc::new(Histogram::new(SIZE_BOUNDS));
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for v in 0..250u64 {
                        h.observe(v % 32);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 1000);
    }
}
