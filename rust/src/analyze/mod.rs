//! Static analysis of guest firmware — without executing it.
//!
//! `femu analyze` (and the server's `analyze` command) runs this over
//! any loadable image: a built-in workload, an assembled `.s` file, or
//! the memory of a restored snapshot. Four products (DESIGN.md §12):
//!
//! * **CFG recovery** ([`cfg`]) — recursive-descent disassembly from the
//!   entry with a sound register constant propagation; basic blocks are
//!   scanned by the *same* [`crate::exec::blocks`] scanner the blocks
//!   backend compiles with, so the static block map is shape-identical
//!   to the backend's superinstruction cache.
//! * **Lint diagnostics** ([`lint`]) — stable `FEMU-Axxx` rules over the
//!   reachable code: memory-map violations, misalignment, SMC
//!   candidates, unreachable text, bad CSR writes, call depth,
//!   unresolved indirect jumps.
//! * **Static WCET / energy bounds** — per-block worst-case cycles from
//!   [`crate::cpu::Timing::worst_cycles`], per-function longest-path
//!   WCET, a program-level cycles-per-instruction bound, and the
//!   all-domains-active energy ceiling
//!   ([`crate::energy::EnergyModel::bound_mj`]). All are *bounds*: the
//!   analyzer tests assert them against measured `perf_snapshot()`
//!   numbers after real runs.
//! * **Block-map export** — [`Report::block_entries`] feeds
//!   [`crate::soc::Soc::precompile`] so the blocks backend can warm its
//!   cache at reset instead of on demand (`femu diff --precompile`
//!   proves the warm-up changes nothing).

pub mod cfg;
pub mod lint;

use std::collections::BTreeMap;

use crate::bus::{MemoryMap, BRIDGE_WAIT, PERIPH_BASE, PERIPH_WAIT};
use crate::config::PlatformConfig;
use crate::cpu::Timing;
use crate::exec::BlockInfo;
use crate::isa::{Instr, Program};
use crate::periph::map;
use crate::soc::Soc;
use crate::util::json::Json;

pub use cfg::{AbsVal, BlockMap, CallGraph, Walk};
pub use lint::{Diagnostic, Severity};

/// Everything the analyzer needs to know about the platform shape —
/// derivable from a [`PlatformConfig`] so `femu analyze --config` lints
/// against the same map/timing/energy data the emulator runs with.
#[derive(Clone, Debug)]
pub struct AnalyzeConfig {
    pub map: MemoryMap,
    pub timing: Timing,
    /// All-active power and the cycle->energy conversion.
    pub energy: crate::energy::EnergyModel,
    /// Worst-case SPI-flash word cost (config-dependent wait states).
    pub flash_cycles_per_word: u32,
    /// FEMU-A006 threshold: deepest allowed static call chain.
    pub max_call_depth: u32,
}

impl AnalyzeConfig {
    pub fn from_platform(cfg: &PlatformConfig) -> Self {
        Self {
            map: MemoryMap::new(cfg.soc.num_banks, cfg.soc.bank_size, cfg.soc.cs_dram_size),
            timing: cfg.timing,
            energy: cfg.energy.clone(),
            flash_cycles_per_word: cfg.soc.flash_timing.cycles_per_word,
            max_call_depth: 64,
        }
    }
}

impl Default for AnalyzeConfig {
    fn default() -> Self {
        Self::from_platform(&PlatformConfig::default())
    }
}

/// A loadable guest image: word-addressed memory, an entry point, and —
/// when known — the text extent (enables the unreachable-code and SMC
/// lints) and symbols (function naming).
pub struct Image {
    words: BTreeMap<u32, u32>,
    pub entry: u32,
    /// `[start, end)` of the text section, when the image came from an
    /// assembled [`Program`]. `None` for raw memory (snapshots).
    pub text_extent: Option<(u32, u32)>,
    pub symbols: BTreeMap<String, u32>,
}

impl Image {
    /// Image of an assembled program, exactly as the loader would place
    /// it (text and data words both land in SRAM and are fetchable).
    pub fn from_program(prog: &Program) -> Self {
        let mut words = BTreeMap::new();
        for (i, &w) in prog.text.iter().enumerate() {
            words.insert(prog.text_base + 4 * i as u32, w);
        }
        for (i, chunk) in prog.data.chunks(4).enumerate() {
            let mut b = [0u8; 4];
            b[..chunk.len()].copy_from_slice(chunk);
            words.insert(prog.data_base + 4 * i as u32, u32::from_le_bytes(b));
        }
        let text_end = prog.text_base + 4 * prog.text.len() as u32;
        Self {
            words,
            entry: prog.entry,
            text_extent: Some((prog.text_base, text_end)),
            symbols: prog.symbols.clone(),
        }
    }

    /// Image of a live (e.g. snapshot-restored) SoC: all of SRAM, entry
    /// at the current pc. No text extent — the unreachable-text and SMC
    /// lints stay quiet rather than guess.
    pub fn from_soc(soc: &Soc) -> Self {
        let mut words = BTreeMap::new();
        let end = soc.bus.memory_map().sram_end();
        let mut addr = 0u32;
        while addr < end {
            if let Some(w) = soc.bus.debug_read32(addr) {
                // zero words never decode; skipping them keeps the map
                // sparse without changing any scan result
                if w != 0 {
                    words.insert(addr, w);
                }
            }
            addr += 4;
        }
        Self { words, entry: soc.cpu.pc, text_extent: None, symbols: BTreeMap::new() }
    }

    /// Word at `pc`, if the image holds one (word-aligned addressing).
    pub fn fetch(&self, pc: u32) -> Option<u32> {
        self.words.get(&pc).copied()
    }

    /// Reverse symbol lookup for report naming.
    fn name_of(&self, pc: u32) -> String {
        symbol_name(&self.symbols, pc)
    }
}

/// The one symbol-naming scheme for function start pcs: the symbol
/// whose value is exactly `pc`, else the stable fallback
/// `fn_<pc:08x>`. Both `femu analyze --json` and the profiler's JSON
/// ([`crate::profile`]) name functions through this helper, so
/// downstream tooling can join static bounds against measured profiles
/// without address fixups.
pub fn symbol_name(symbols: &BTreeMap<String, u32>, pc: u32) -> String {
    symbols
        .iter()
        .find(|(_, &v)| v == pc)
        .map(|(k, _)| k.clone())
        .unwrap_or_else(|| format!("fn_{pc:08x}"))
}

/// Per-function line of the report.
#[derive(Clone, Debug)]
pub struct FunctionReport {
    pub name: String,
    pub entry: u32,
    pub blocks: usize,
    /// Longest acyclic path in cycles; `None` = the function can loop,
    /// so no finite static bound exists.
    pub wcet_cycles: Option<u64>,
    /// Entry pcs of statically-resolved callees (sorted, deduped) —
    /// the call edges the profiler's inclusive view and folded stacks
    /// roll up over.
    pub calls: Vec<u32>,
}

/// The full analysis result.
pub struct Report {
    pub name: String,
    pub entry: u32,
    /// Reachable instructions.
    pub instructions: usize,
    /// Statically recovered block map (sorted by pc), shape-identical to
    /// what the blocks backend builds ([`crate::soc::Soc::block_map`]).
    pub blocks: Vec<BlockInfo>,
    pub functions: Vec<FunctionReport>,
    /// Longest static call chain (1 = no calls).
    pub call_depth: u32,
    /// Worst-case cycles any single reachable instruction can cost,
    /// including bus wait states — so `instret * cpi_bound` bounds the
    /// cycle count of any non-sleeping run.
    pub cpi_bound: u64,
    /// All-domains-active platform power (the energy-bound slope).
    pub active_power_mw: f64,
    pub freq_hz: u64,
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    pub fn errors(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn warnings(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    pub fn clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Sorted block-entry pcs — feed to [`crate::soc::Soc::precompile`].
    pub fn block_entries(&self) -> Vec<u32> {
        self.blocks.iter().map(|b| b.pc).collect()
    }

    /// The symbol view the profiler folds captures with
    /// ([`crate::profile::FunctionTable`]): function entries under the
    /// shared [`symbol_name`] scheme, plus the static call edges, with
    /// the analysis entry as the folded-stack root.
    pub fn function_table(&self) -> crate::profile::FunctionTable {
        let entries = self.functions.iter().map(|f| (f.entry, f.name.clone())).collect();
        let calls = self.functions.iter().map(|f| (f.entry, f.calls.clone())).collect();
        crate::profile::FunctionTable::new(entries, calls, self.entry)
    }

    /// Static cycle bound for a run retiring `instret` instructions
    /// (valid for runs with no WFI sleep residency).
    pub fn cycle_bound(&self, instret: u64) -> u64 {
        instret.saturating_mul(self.cpi_bound)
    }

    /// Static energy ceiling for a run of at most `cycles` cycles: all
    /// domains active the whole time (mirrors
    /// [`crate::energy::EnergyModel::bound_mj`]).
    pub fn energy_bound_mj(&self, cycles: u64) -> f64 {
        self.active_power_mw * cycles as f64 / self.freq_hz as f64
    }

    /// The machine-readable report (schema documented in README).
    pub fn to_json(&self) -> Json {
        let blocks: Vec<Json> = self
            .blocks
            .iter()
            .map(|b| {
                Json::obj(vec![
                    ("pc", Json::Num(b.pc as f64)),
                    ("len", Json::Num(b.len as f64)),
                    ("max_cycles", Json::Num(b.max_cycles as f64)),
                ])
            })
            .collect();
        let functions: Vec<Json> = self
            .functions
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("name", Json::Str(f.name.clone())),
                    ("entry", Json::Num(f.entry as f64)),
                    ("blocks", Json::Num(f.blocks as f64)),
                    (
                        "wcet_cycles",
                        f.wcet_cycles.map(|c| Json::Num(c as f64)).unwrap_or(Json::Null),
                    ),
                    (
                        "calls",
                        Json::Arr(f.calls.iter().map(|&c| Json::Num(c as f64)).collect()),
                    ),
                ])
            })
            .collect();
        let diagnostics: Vec<Json> = self
            .diagnostics
            .iter()
            .map(|d| {
                Json::obj(vec![
                    ("rule", Json::Str(d.rule.to_string())),
                    ("severity", Json::Str(d.severity.name().to_string())),
                    ("pc", d.pc.map(|pc| Json::Num(pc as f64)).unwrap_or(Json::Null)),
                    ("message", Json::Str(d.message.clone())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("entry", Json::Num(self.entry as f64)),
            ("instructions", Json::Num(self.instructions as f64)),
            ("block_map", Json::Arr(blocks)),
            ("functions", Json::Arr(functions)),
            ("call_depth", Json::Num(self.call_depth as f64)),
            ("cpi_bound", Json::Num(self.cpi_bound as f64)),
            ("active_power_mw", Json::Num(self.active_power_mw)),
            ("freq_hz", Json::Num(self.freq_hz as f64)),
            ("diagnostics", Json::Arr(diagnostics)),
            (
                "summary",
                Json::obj(vec![
                    ("errors", Json::Num(self.errors() as f64)),
                    ("warnings", Json::Num(self.warnings() as f64)),
                    ("blocks", Json::Num(self.blocks.len() as f64)),
                ]),
            ),
        ])
    }

    /// The human-readable report.
    pub fn render_text(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "analyze {}: entry {:#010x}, {} reachable instructions, {} blocks, {} functions",
            self.name,
            self.entry,
            self.instructions,
            self.blocks.len(),
            self.functions.len(),
        );
        let _ = writeln!(
            s,
            "  bounds: <= {} cycles/instr; all-active power {:.3} mW ({:.3} mJ per Mcycle)",
            self.cpi_bound,
            self.active_power_mw,
            self.energy_bound_mj(1_000_000),
        );
        for f in &self.functions {
            let wcet = match f.wcet_cycles {
                Some(c) => format!("{c} cycles"),
                None => "unbounded (loops)".to_string(),
            };
            let _ = writeln!(
                s,
                "  fn {} @ {:#010x}: {} blocks, static WCET {}",
                f.name, f.entry, f.blocks, wcet
            );
        }
        let _ = writeln!(s, "  call depth: {}", self.call_depth);
        let _ = writeln!(s, "  block map ({} entries):", self.blocks.len());
        for b in &self.blocks {
            let _ = writeln!(
                s,
                "    {:#010x}  len {:>3}  max {:>4} cycles",
                b.pc, b.len, b.max_cycles
            );
        }
        if self.diagnostics.is_empty() {
            let _ = writeln!(s, "  diagnostics: none");
        } else {
            let _ = writeln!(
                s,
                "  diagnostics: {} error(s), {} warning(s)",
                self.errors(),
                self.warnings()
            );
            for d in &self.diagnostics {
                let at = d.pc.map(|pc| format!(" @ {pc:#010x}")).unwrap_or_default();
                let _ =
                    writeln!(s, "    {} {}{at}: {}", d.rule, d.severity.name(), d.message);
            }
        }
        s
    }
}

/// Worst-case extra bus wait states for one instruction: the proven
/// window's cost where the address resolved, otherwise the maximum any
/// window can charge (sound for `Top` addresses).
fn wait_bound(cfg: &AnalyzeConfig, instr: Instr, state: &cfg::RegState) -> u32 {
    if !cfg::is_mem_access(instr) {
        return 0;
    }
    let spi_worst = crate::periph::spi_adc::WORD_CYCLES.max(cfg.flash_cycles_per_word);
    match cfg::access_addr(instr, state) {
        Some((addr, _, _)) => match cfg.map.region(addr) {
            crate::bus::Region::Sram => 0,
            crate::bus::Region::Periph => {
                let dev = (addr - PERIPH_BASE) & !(map::WINDOW - 1);
                let extra = match dev {
                    map::SPI_ADC => crate::periph::spi_adc::WORD_CYCLES,
                    map::SPI_FLASH => cfg.flash_cycles_per_word,
                    _ => 0,
                };
                PERIPH_WAIT + extra
            }
            crate::bus::Region::Bridge => BRIDGE_WAIT,
            // unmapped: traps (already counted via worst_cycles), and
            // linted as FEMU-A001
            crate::bus::Region::Unmapped => 0,
        },
        None => BRIDGE_WAIT.max(PERIPH_WAIT + spi_worst),
    }
}

/// Program-level cycles-per-instruction bound: the most any single
/// reachable instruction can cost, base class cost plus wait states.
fn cpi_bound(cfg: &AnalyzeConfig, walk: &Walk) -> u64 {
    let mut worst = 1u64;
    for (pc, &instr) in &walk.instrs {
        let state = &walk.states[pc];
        let mut c = cfg.timing.worst_cycles(instr) as u64 + wait_bound(cfg, instr, state) as u64;
        if matches!(instr, Instr::Wfi) {
            // wake-up cost on top of the base class cost (sleep
            // residency itself is unbounded and excluded by contract)
            c += cfg.timing.wake as u64;
        }
        worst = worst.max(c);
    }
    worst
}

/// Analyze an image end to end.
pub fn analyze(image: &Image, name: &str, cfg: &AnalyzeConfig) -> Report {
    let walk = cfg::walk(image, &cfg.map);
    let blocks = cfg::recover_blocks(image, &walk, cfg);
    let graph = cfg::call_graph(image.entry, &blocks, &walk);
    let diagnostics = lint::run(image, cfg, &walk, &graph);

    let functions = graph
        .functions
        .values()
        .map(|f| FunctionReport {
            name: image.name_of(f.entry),
            entry: f.entry,
            blocks: f.blocks,
            wcet_cycles: f.wcet_cycles,
            calls: f.calls.iter().copied().collect(),
        })
        .collect();

    Report {
        name: name.to_string(),
        entry: image.entry,
        instructions: walk.instrs.len(),
        blocks: blocks.infos(),
        functions,
        call_depth: graph.max_depth,
        cpi_bound: cpi_bound(cfg, &walk),
        active_power_mw: cfg.energy.active_power_mw(cfg.map.num_banks),
        freq_hz: cfg.energy.freq_hz,
        diagnostics,
    }
}

/// Analyze an assembled program.
pub fn analyze_program(prog: &Program, name: &str, cfg: &AnalyzeConfig) -> Report {
    analyze(&Image::from_program(prog), name, cfg)
}

/// Analyze a live SoC's memory from its current pc (the
/// `--from-snapshot` and server paths).
pub fn analyze_soc(soc: &Soc, name: &str, cfg: &AnalyzeConfig) -> Report {
    analyze(&Image::from_soc(soc), name, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::assemble;

    fn report_for(src: &str) -> Report {
        let prog = assemble(src).unwrap();
        analyze_program(&prog, "test", &AnalyzeConfig::default())
    }

    #[test]
    fn straight_line_program_is_clean_and_bounded() {
        let r = report_for(
            r#"
            _start:
                li a0, 5
                li a1, 7
                add a2, a0, a1
                ebreak
            "#,
        );
        assert!(r.clean(), "{:?}", r.diagnostics);
        assert_eq!(r.blocks.len(), 1);
        assert_eq!(r.instructions, 4);
        assert_eq!(r.functions.len(), 1);
        // loop-free: a finite WCET exists and covers the 4 instructions
        let wcet = r.functions[0].wcet_cycles.unwrap();
        assert!(wcet >= 4, "{wcet}");
        assert!(r.cpi_bound >= 1);
        assert!(r.energy_bound_mj(1000) > 0.0);
    }

    #[test]
    fn loop_has_unbounded_function_wcet_but_finite_cpi() {
        let r = report_for(
            r#"
            _start:
                li t0, 10
            loop:
                addi t0, t0, -1
                bnez t0, loop
                ebreak
            "#,
        );
        assert!(r.clean(), "{:?}", r.diagnostics);
        assert_eq!(r.functions[0].wcet_cycles, None);
        assert!(r.cpi_bound >= 1);
    }

    #[test]
    fn call_and_return_resolve_statically() {
        // single call site: ra stays Const through the callee, so the
        // ret resolves and the whole thing is loop-free with a WCET
        let r = report_for(
            r#"
            _start:
                jal ra, leaf
                ebreak
            leaf:
                addi a0, a0, 1
                ret
            "#,
        );
        assert!(r.clean(), "{:?}", r.diagnostics);
        assert_eq!(r.call_depth, 2);
        assert_eq!(r.functions.len(), 2);
        for f in &r.functions {
            assert!(f.wcet_cycles.is_some(), "{} unbounded", f.name);
        }
        let main = r.functions.iter().find(|f| f.name == "_start").unwrap();
        let leaf = r.functions.iter().find(|f| f.name == "leaf").unwrap();
        assert!(main.wcet_cycles.unwrap() > leaf.wcet_cycles.unwrap());
    }

    #[test]
    fn block_map_matches_backend_shapes() {
        // run the same guest on the blocks backend and compare shapes
        let src = r#"
            _start:
                li t0, 3
            loop:
                addi t0, t0, -1
                bnez t0, loop
                ebreak
        "#;
        let prog = assemble(src).unwrap();
        let r = analyze_program(&prog, "shapes", &AnalyzeConfig::default());

        let mut soc_cfg = crate::soc::SocConfig::default();
        soc_cfg.backend = crate::exec::BackendKind::Blocks;
        let mut soc = Soc::new(soc_cfg);
        soc.load(&prog).unwrap();
        soc.run_to_halt(1 << 20);
        assert_eq!(soc.block_map(), r.blocks);
        assert_eq!(soc.exec_stats().blocks_built as usize, r.blocks.len());
    }

    #[test]
    fn json_report_round_trips() {
        let r = report_for("_start: ebreak");
        let text = r.to_json().to_string();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("name").unwrap().as_str().unwrap(), "test");
        assert_eq!(
            parsed.get("summary").unwrap().get("errors").unwrap().as_i64().unwrap(),
            0
        );
        assert!(r.render_text().contains("diagnostics: none"));
    }

    #[test]
    fn from_soc_image_analyzes_loaded_memory() {
        let prog = assemble("_start: li a0, 1\nebreak").unwrap();
        let mut soc = Soc::new(crate::soc::SocConfig::default());
        soc.load(&prog).unwrap();
        let r = analyze_soc(&soc, "mem", &AnalyzeConfig::default());
        assert!(r.clean(), "{:?}", r.diagnostics);
        assert_eq!(r.instructions, 2); // addi + ebreak
    }
}
