//! CFG recovery over a guest image: recursive-descent disassembly with a
//! sound register constant propagation, block-entry closure, and call
//! graph / static WCET extraction.
//!
//! Two cooperating passes:
//!
//! 1. [`walk`] — an instruction-level abstract interpretation from the
//!    image entry. The abstract domain is per-register `Top | Const`
//!    ([`AbsVal`]), joined pointwise at control-flow merges. Constants
//!    fold through the *interpreter's own* ALU (`cpu::alu`), so a
//!    resolved address can never disagree with what execution computes.
//!    The walk yields the reachable-instruction set, the joined in-state
//!    per pc, resolved call edges, unresolved indirect jumps, and
//!    control flow into unfetchable/undecodable words.
//!
//! 2. [`recover_blocks`] — the block-entry closure. Blocks are scanned
//!    with the *same* [`scan_block`] the blocks backend compiles with,
//!    so the statically recovered block map is shape-identical to what
//!    the backend builds at dispatch time, including the device-access
//!    split points where a dispatched block bails out and execution
//!    re-enters one instruction later (see DESIGN.md §12).
//!
//! On top of those, [`call_graph`] computes per-function static WCET
//! (longest acyclic block path; `None` when the function can loop) and
//! the maximum static call depth.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::bus::{MemoryMap, Region};
use crate::exec::blocks::{is_terminator, scan_block};
use crate::exec::BlockInfo;
use crate::isa::{self, Instr, LoadOp, StoreOp};

use super::{AnalyzeConfig, Image};

/// Abstract register value: statically known constant, or anything.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AbsVal {
    Top,
    Const(u32),
}

impl AbsVal {
    pub fn join(self, other: AbsVal) -> AbsVal {
        if self == other {
            self
        } else {
            AbsVal::Top
        }
    }

    pub fn constant(self) -> Option<u32> {
        match self {
            AbsVal::Const(c) => Some(c),
            AbsVal::Top => None,
        }
    }
}

/// Abstract register file. `x0` is pinned to `Const(0)`.
pub type RegState = [AbsVal; 32];

fn initial_state() -> RegState {
    let mut s = [AbsVal::Top; 32];
    s[0] = AbsVal::Const(0);
    s
}

fn join_states(a: &RegState, b: &RegState) -> RegState {
    let mut out = *a;
    for (o, r) in out.iter_mut().zip(b.iter()) {
        *o = o.join(*r);
    }
    out
}

fn set_reg(state: &mut RegState, rd: u8, v: AbsVal) {
    if rd != 0 {
        state[rd as usize] = v;
    }
}

/// Abstract transfer function for one instruction (registers only; memory
/// is not tracked, so every load produces `Top`).
fn transfer(instr: Instr, pc: u32, state: &RegState) -> RegState {
    let mut out = *state;
    match instr {
        Instr::Lui { rd, imm } => set_reg(&mut out, rd, AbsVal::Const(imm as u32)),
        Instr::Auipc { rd, imm } => {
            set_reg(&mut out, rd, AbsVal::Const(pc.wrapping_add(imm as u32)))
        }
        Instr::OpImm { op, rd, rs1, imm } => {
            let v = match state[rs1 as usize].constant() {
                Some(a) => AbsVal::Const(crate::cpu::alu(op, a, imm as u32)),
                None => AbsVal::Top,
            };
            set_reg(&mut out, rd, v);
        }
        Instr::Op { op, rd, rs1, rs2 } => {
            let v = match (state[rs1 as usize].constant(), state[rs2 as usize].constant()) {
                (Some(a), Some(b)) => AbsVal::Const(crate::cpu::alu(op, a, b)),
                _ => AbsVal::Top,
            };
            set_reg(&mut out, rd, v);
        }
        Instr::Load { rd, .. } => set_reg(&mut out, rd, AbsVal::Top),
        Instr::Jal { rd, .. } | Instr::Jalr { rd, .. } => {
            set_reg(&mut out, rd, AbsVal::Const(pc.wrapping_add(4)))
        }
        Instr::Csr { rd, .. } => set_reg(&mut out, rd, AbsVal::Top),
        _ => {}
    }
    out
}

/// The statically known effective address of a load/store, if any, plus
/// its access size in bytes.
pub fn access_addr(instr: Instr, state: &RegState) -> Option<(u32, u32, bool)> {
    match instr {
        Instr::Load { op, rs1, imm, .. } => {
            let size = match op {
                LoadOp::Lb | LoadOp::Lbu => 1,
                LoadOp::Lh | LoadOp::Lhu => 2,
                LoadOp::Lw => 4,
            };
            state[rs1 as usize].constant().map(|b| (b.wrapping_add(imm as u32), size, false))
        }
        Instr::Store { op, rs1, imm, .. } => {
            let size = match op {
                StoreOp::Sb => 1,
                StoreOp::Sh => 2,
                StoreOp::Sw => 4,
            };
            state[rs1 as usize].constant().map(|b| (b.wrapping_add(imm as u32), size, true))
        }
        _ => None,
    }
}

/// Is this load/store a memory access at all (even with unknown target)?
pub fn is_mem_access(instr: Instr) -> bool {
    matches!(instr, Instr::Load { .. } | Instr::Store { .. })
}

/// Why a control-flow edge could not be followed.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum FlowKind {
    /// Target lies outside the (only executable) SRAM window.
    OutsideSram,
    /// Target is not 4-byte aligned.
    Misaligned,
    /// Target is in SRAM but holds no decodable instruction.
    Undecodable,
}

/// The result of the instruction-level abstract interpretation.
pub struct Walk {
    /// Joined register in-state per reachable pc.
    pub states: BTreeMap<u32, RegState>,
    /// Decoded instruction per reachable pc.
    pub instrs: BTreeMap<u32, Instr>,
    /// `jalr` sites whose base register joined to `Top`.
    pub unresolved: BTreeSet<u32>,
    /// `(site, target, kind)`: control-flow edges that leave the
    /// executable world.
    pub bad_flow: BTreeSet<(u32, u32, FlowKind)>,
    /// Resolved call edges `(site, callee)` — `jal`/`jalr` with `rd != x0`.
    pub calls: BTreeSet<(u32, u32)>,
}

/// Run the abstract interpretation from `image.entry`. Terminates because
/// the per-pc state only moves up a two-level lattice.
pub fn walk(image: &Image, map: &MemoryMap) -> Walk {
    let mut w = Walk {
        states: BTreeMap::new(),
        instrs: BTreeMap::new(),
        unresolved: BTreeSet::new(),
        bad_flow: BTreeSet::new(),
        calls: BTreeSet::new(),
    };
    let mut work: VecDeque<u32> = VecDeque::new();

    let entry = image.entry;
    if entry % 4 != 0 {
        w.bad_flow.insert((entry, entry, FlowKind::Misaligned));
        return w;
    }
    if map.region(entry) != Region::Sram {
        w.bad_flow.insert((entry, entry, FlowKind::OutsideSram));
        return w;
    }
    if image.fetch(entry).and_then(isa::decode).is_none() {
        w.bad_flow.insert((entry, entry, FlowKind::Undecodable));
        return w;
    }
    w.states.insert(entry, initial_state());
    work.push_back(entry);

    while let Some(pc) = work.pop_front() {
        let state = w.states[&pc];
        // enqueue sites are pre-validated, so both unwraps hold
        let instr = isa::decode(image.fetch(pc).unwrap()).unwrap();
        w.instrs.insert(pc, instr);
        let out = transfer(instr, pc, &state);

        let mut succs: Vec<u32> = Vec::new();
        match instr {
            Instr::Branch { imm, .. } => {
                succs.push(pc.wrapping_add(imm as u32));
                succs.push(pc.wrapping_add(4));
            }
            Instr::Jal { rd, imm } => {
                let target = pc.wrapping_add(imm as u32);
                succs.push(target);
                if rd != 0 {
                    w.calls.insert((pc, target));
                    // the return site is reachable iff the callee
                    // returns; assumed here so callers never lint as
                    // unreachable (documented over-approximation)
                    succs.push(pc.wrapping_add(4));
                }
            }
            Instr::Jalr { rd, rs1, imm } => match state[rs1 as usize].constant() {
                Some(base) => {
                    let target = base.wrapping_add(imm as u32) & !1;
                    succs.push(target);
                    if rd != 0 {
                        w.calls.insert((pc, target));
                        succs.push(pc.wrapping_add(4));
                    }
                }
                None => {
                    w.unresolved.insert(pc);
                    if rd != 0 {
                        succs.push(pc.wrapping_add(4));
                    }
                }
            },
            // ecall: target depends on a runtime mtvec value; ebreak
            // halts; mret: mepc is not tracked
            Instr::Ecall | Instr::Ebreak | Instr::Mret => {}
            _ => succs.push(pc.wrapping_add(4)),
        }

        for t in succs {
            if t % 4 != 0 {
                w.bad_flow.insert((pc, t, FlowKind::Misaligned));
                continue;
            }
            if map.region(t) != Region::Sram {
                w.bad_flow.insert((pc, t, FlowKind::OutsideSram));
                continue;
            }
            if image.fetch(t).and_then(isa::decode).is_none() {
                w.bad_flow.insert((pc, t, FlowKind::Undecodable));
                continue;
            }
            match w.states.get(&t) {
                Some(prev) => {
                    let joined = join_states(prev, &out);
                    if joined != *prev {
                        w.states.insert(t, joined);
                        work.push_back(t);
                    }
                }
                None => {
                    w.states.insert(t, out);
                    work.push_back(t);
                }
            }
        }
    }
    w
}

/// How a block hands off control, at the call/return level (used by the
/// WCET path search; the block-entry closure uses finer successor sets).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BlockExit {
    /// Plain intra-function successors (branch arms, jumps, fallthrough
    /// after a cut or a CSR/WFI terminator).
    Jump(Vec<u32>),
    /// Ends in a call: control resumes at `ret` after `callee` finishes.
    /// `callee` is `None` for an unresolved indirect call.
    Call { callee: Option<u32>, ret: u32 },
    /// Function return (`jalr x0, ...` or `mret`).
    Return,
    /// Execution stops here (`ebreak`, `ecall`, dead end).
    Halt,
}

/// The statically recovered block map.
pub struct BlockMap {
    /// Block entry -> shape (identical to the backend's [`BlockInfo`]).
    pub blocks: BTreeMap<u32, BlockInfo>,
    /// Block entry -> call/return-level exit.
    pub exits: BTreeMap<u32, BlockExit>,
}

impl BlockMap {
    /// Sorted block-entry pcs — the precompile export consumed by
    /// [`crate::soc::Soc::precompile`].
    pub fn entries(&self) -> Vec<u32> {
        self.blocks.keys().copied().collect()
    }

    /// Sorted [`BlockInfo`] list, directly comparable with
    /// [`crate::soc::Soc::block_map`] after a run.
    pub fn infos(&self) -> Vec<BlockInfo> {
        self.blocks.values().copied().collect()
    }
}

/// Closure over block entries from the image entry, mirroring how the
/// blocks backend discovers entries at dispatch time:
///
/// * terminator targets and fallthroughs become entries;
/// * a block cut by length or a 512-B generation-page boundary continues
///   at the next pc;
/// * the *first* statically certain non-SRAM access in a block splits it:
///   the backend bails out there, single-steps the device access, and
///   compiles a fresh block right after it.
pub fn recover_blocks(image: &Image, w: &Walk, cfg: &AnalyzeConfig) -> BlockMap {
    let mut map = BlockMap { blocks: BTreeMap::new(), exits: BTreeMap::new() };
    let mut work: VecDeque<u32> = VecDeque::new();
    work.push_back(image.entry);

    while let Some(entry) = work.pop_front() {
        if map.blocks.contains_key(&entry) {
            continue;
        }
        if entry % 4 != 0 || cfg.map.region(entry) != Region::Sram {
            continue;
        }
        let (body, max_cycles) = scan_block(&cfg.timing, entry, &mut |p| image.fetch(p));
        if body.is_empty() {
            // the backend's build fails here too: no block, the
            // interpreter single-steps into the trap path
            continue;
        }
        map.blocks.insert(
            entry,
            BlockInfo { pc: entry, len: body.len() as u32, max_cycles },
        );

        let mut succs: Vec<u32> = Vec::new();

        // device-access split: the first body instruction with a
        // statically certain non-SRAM target makes the backend bail
        for (i, &(instr, _)) in body.iter().enumerate() {
            let pc = entry + 4 * i as u32;
            let Some(state) = w.states.get(&pc) else { continue };
            let Some((addr, _, _)) = access_addr(instr, state) else { continue };
            if cfg.map.region(addr) != Region::Sram {
                // bail at pc: a block gets built there (guard declines
                // it when the access is at index 0, and execution
                // re-enters at pc+4 after the single step)
                succs.push(if i == 0 { entry + 4 } else { pc });
                break;
            }
        }

        let (last_instr, _) = *body.last().unwrap();
        let last_pc = entry + 4 * (body.len() as u32 - 1);
        let next = entry + 4 * body.len() as u32;
        let exit = if !is_terminator(last_instr) {
            // cut by MAX_BLOCK_LEN or a page boundary (or a dead end —
            // then the scan at `next` comes back empty and is dropped)
            succs.push(next);
            BlockExit::Jump(vec![next])
        } else {
            match last_instr {
                Instr::Branch { imm, .. } => {
                    let t = last_pc.wrapping_add(imm as u32);
                    succs.push(t);
                    succs.push(next);
                    BlockExit::Jump(vec![t, next])
                }
                Instr::Jal { rd, imm } => {
                    let t = last_pc.wrapping_add(imm as u32);
                    succs.push(t);
                    if rd != 0 {
                        succs.push(next);
                        BlockExit::Call { callee: Some(t), ret: next }
                    } else {
                        BlockExit::Jump(vec![t])
                    }
                }
                Instr::Jalr { rd, rs1, .. } => {
                    let resolved = w
                        .states
                        .get(&last_pc)
                        .and_then(|s| s[rs1 as usize].constant())
                        .map(|base| {
                            let Instr::Jalr { imm, .. } = last_instr else { unreachable!() };
                            base.wrapping_add(imm as u32) & !1
                        });
                    if let Some(t) = resolved {
                        succs.push(t);
                    }
                    if rd != 0 {
                        succs.push(next);
                        BlockExit::Call { callee: resolved, ret: next }
                    } else {
                        // rd = x0: conventionally a return (or an
                        // unresolvable indirect jump, linted separately)
                        BlockExit::Return
                    }
                }
                Instr::Csr { .. } | Instr::Wfi => {
                    succs.push(next);
                    BlockExit::Jump(vec![next])
                }
                Instr::Mret => BlockExit::Return,
                _ => BlockExit::Halt, // ecall / ebreak
            }
        };
        map.exits.insert(entry, exit);

        for s in succs {
            if !map.blocks.contains_key(&s) {
                work.push_back(s);
            }
        }
    }
    map
}

/// Per-function summary out of the call-graph pass.
#[derive(Clone, Debug)]
pub struct FunctionInfo {
    pub entry: u32,
    /// Blocks reachable from the entry without crossing a call edge.
    pub blocks: usize,
    /// Longest acyclic block path in cycles, with callee WCETs inlined
    /// at call sites; `None` when the function (or a callee) can loop.
    pub wcet_cycles: Option<u64>,
    /// Resolved callee entries.
    pub calls: BTreeSet<u32>,
}

/// Call-graph analysis result.
pub struct CallGraph {
    /// Function entry -> summary; always contains the image entry.
    pub functions: BTreeMap<u32, FunctionInfo>,
    /// Longest call chain from the root (1 = no calls).
    pub max_depth: u32,
    /// A call cycle is statically reachable.
    pub recursive: bool,
}

/// Discover functions (the image entry plus every resolved call target),
/// then compute per-function WCET and the maximum static call depth.
pub fn call_graph(root: u32, map: &BlockMap, w: &Walk) -> CallGraph {
    // function entries: root + all resolved call targets
    let mut entries: BTreeSet<u32> = BTreeSet::new();
    entries.insert(root);
    for &(_, callee) in &w.calls {
        entries.insert(callee);
    }

    // intra-function block sets + call edges
    let mut functions: BTreeMap<u32, FunctionInfo> = BTreeMap::new();
    for &f in &entries {
        let mut seen: BTreeSet<u32> = BTreeSet::new();
        let mut calls: BTreeSet<u32> = BTreeSet::new();
        let mut stack = vec![f];
        while let Some(b) = stack.pop() {
            if !seen.insert(b) {
                continue;
            }
            match map.exits.get(&b) {
                Some(BlockExit::Jump(ts)) => {
                    for &t in ts {
                        if map.blocks.contains_key(&t) {
                            stack.push(t);
                        }
                    }
                }
                Some(BlockExit::Call { callee, ret }) => {
                    if let Some(c) = callee {
                        calls.insert(*c);
                    }
                    if map.blocks.contains_key(ret) {
                        stack.push(*ret);
                    }
                }
                Some(BlockExit::Return) | Some(BlockExit::Halt) | None => {}
            }
        }
        let blocks = seen.iter().filter(|b| map.blocks.contains_key(b)).count();
        functions.insert(f, FunctionInfo { entry: f, blocks, wcet_cycles: None, calls });
    }

    // call depth (DFS with cycle detection)
    let mut recursive = false;
    let mut depth_memo: BTreeMap<u32, u32> = BTreeMap::new();
    let mut stack_set: BTreeSet<u32> = BTreeSet::new();
    fn depth(
        f: u32,
        functions: &BTreeMap<u32, FunctionInfo>,
        memo: &mut BTreeMap<u32, u32>,
        on_stack: &mut BTreeSet<u32>,
        recursive: &mut bool,
    ) -> u32 {
        if let Some(&d) = memo.get(&f) {
            return d;
        }
        if !on_stack.insert(f) {
            *recursive = true;
            return 0;
        }
        let mut best = 0;
        if let Some(info) = functions.get(&f) {
            for &c in &info.calls {
                best = best.max(depth(c, functions, memo, on_stack, recursive));
            }
        }
        on_stack.remove(&f);
        memo.insert(f, best + 1);
        best + 1
    }
    let max_depth = depth(root, &functions, &mut depth_memo, &mut stack_set, &mut recursive);

    // per-function WCET, callees inlined (lazy, memoized, cycle -> None)
    #[allow(clippy::too_many_arguments)]
    fn fn_wcet(
        f: u32,
        map: &BlockMap,
        functions: &BTreeMap<u32, FunctionInfo>,
        memo: &mut BTreeMap<u32, Option<u64>>,
        on_stack: &mut BTreeSet<u32>,
    ) -> Option<u64> {
        if let Some(v) = memo.get(&f) {
            return *v;
        }
        if !on_stack.insert(f) {
            return None; // recursion: unbounded
        }
        let mut block_memo: BTreeMap<u32, Option<u64>> = BTreeMap::new();
        let mut block_stack: BTreeSet<u32> = BTreeSet::new();
        #[allow(clippy::too_many_arguments)]
        fn longest(
            b: u32,
            map: &BlockMap,
            functions: &BTreeMap<u32, FunctionInfo>,
            fmemo: &mut BTreeMap<u32, Option<u64>>,
            fstack: &mut BTreeSet<u32>,
            bmemo: &mut BTreeMap<u32, Option<u64>>,
            bstack: &mut BTreeSet<u32>,
        ) -> Option<u64> {
            let Some(info) = map.blocks.get(&b) else { return Some(0) };
            if let Some(v) = bmemo.get(&b) {
                return *v;
            }
            if !bstack.insert(b) {
                return None; // loop in the block graph: unbounded
            }
            let tail = match map.exits.get(&b) {
                Some(BlockExit::Jump(ts)) => {
                    let mut best: Option<u64> = Some(0);
                    for &t in ts {
                        match (
                            best,
                            longest(t, map, functions, fmemo, fstack, bmemo, bstack),
                        ) {
                            (Some(a), Some(c)) => best = Some(a.max(c)),
                            _ => {
                                best = None;
                                break;
                            }
                        }
                    }
                    best
                }
                Some(BlockExit::Call { callee, ret }) => {
                    let callee_cost = match callee {
                        Some(c) => fn_wcet(*c, map, functions, fmemo, fstack),
                        None => None,
                    };
                    let ret_cost =
                        longest(*ret, map, functions, fmemo, fstack, bmemo, bstack);
                    match (callee_cost, ret_cost) {
                        (Some(a), Some(b)) => Some(a + b),
                        _ => None,
                    }
                }
                Some(BlockExit::Return) | Some(BlockExit::Halt) | None => Some(0),
            };
            bstack.remove(&b);
            let total = tail.map(|t| t + info.max_cycles);
            bmemo.insert(b, total);
            total
        }
        let result = longest(
            f,
            map,
            functions,
            memo,
            on_stack,
            &mut block_memo,
            &mut block_stack,
        );
        on_stack.remove(&f);
        memo.insert(f, result);
        result
    }

    let mut wcet_memo: BTreeMap<u32, Option<u64>> = BTreeMap::new();
    let fn_entries: Vec<u32> = functions.keys().copied().collect();
    for f in fn_entries {
        let mut on_stack = BTreeSet::new();
        let wcet = fn_wcet(f, map, &functions, &mut wcet_memo, &mut on_stack);
        if let Some(info) = functions.get_mut(&f) {
            info.wcet_cycles = wcet;
        }
    }

    CallGraph { functions, max_depth, recursive }
}
