//! Lint diagnostics over the recovered CFG — stable `FEMU-Axxx` rules.
//!
//! Every rule keys off facts the walk proved, never heuristics over raw
//! bytes: an address is only checked when constant propagation resolved
//! it, a CSR is only flagged by the *core's own* implementation tables
//! ([`crate::cpu::Csrs::is_known`] / [`Csrs::is_read_only`]), and the
//! SMC rule uses the exact write-generation page granularity the blocks
//! backend invalidates on ([`crate::mem::GEN_PAGE_SHIFT`]). `Top`
//! addresses are never linted — the analyzer stays silent rather than
//! guess (DESIGN.md §12 lists the resulting blind spots).

use crate::bus::{Region, PERIPH_BASE};
use crate::cpu::Csrs;
use crate::isa::{CsrOp, Instr};
use crate::mem::GEN_PAGE_SHIFT;
use crate::periph::map;

use super::cfg::{access_addr, FlowKind, Walk};
use super::{AnalyzeConfig, CallGraph, Image};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One finding. `pc` is the offending instruction site, or `None` for
/// program-level findings (call depth).
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub rule: &'static str,
    pub severity: Severity,
    pub pc: Option<u32>,
    pub message: String,
}

pub const A001: &str = "FEMU-A001"; // memory-map violation
pub const A002: &str = "FEMU-A002"; // misaligned access or jump target
pub const A003: &str = "FEMU-A003"; // self-modifying-code candidate
pub const A004: &str = "FEMU-A004"; // unreachable text
pub const A005: &str = "FEMU-A005"; // bad CSR access
pub const A006: &str = "FEMU-A006"; // call depth / recursion
pub const A007: &str = "FEMU-A007"; // unresolved indirect jump

/// The rule catalog: `(id, severity, summary)`.
pub const CATALOG: &[(&str, Severity, &str)] = &[
    (A001, Severity::Error, "access or jump outside the platform memory map"),
    (A002, Severity::Error, "misaligned access or jump target (traps at runtime)"),
    (A003, Severity::Warning, "store into a text page (self-modifying-code candidate)"),
    (A004, Severity::Warning, "text never reachable from the entry point"),
    (A005, Severity::Error, "unimplemented CSR, or write to a read-only CSR"),
    (A006, Severity::Warning, "recursion or call chain deeper than the configured limit"),
    (A007, Severity::Warning, "indirect jump target not statically resolvable"),
];

fn push(
    out: &mut Vec<Diagnostic>,
    rule: &'static str,
    pc: Option<u32>,
    message: String,
) {
    let severity = CATALOG
        .iter()
        .find(|(id, _, _)| *id == rule)
        .map(|&(_, s, _)| s)
        .unwrap_or(Severity::Error);
    out.push(Diagnostic { rule, severity, pc, message });
}

/// Known-device check: an address inside the peripheral region must fall
/// in an implemented device window (anything past the mailbox faults).
fn periph_device(addr: u32) -> Option<&'static str> {
    let dev = (addr - PERIPH_BASE) & !(map::WINDOW - 1);
    match dev {
        map::UART => Some("uart"),
        map::GPIO => Some("gpio"),
        map::TIMER => Some("timer"),
        map::SPI_ADC => Some("spi-adc"),
        map::SPI_FLASH => Some("spi-flash"),
        map::DMA => Some("dma"),
        map::POWER => Some("power"),
        map::CGRA => Some("cgra"),
        map::MAILBOX => Some("mailbox"),
        _ => None,
    }
}

/// Run every rule over the walk results; diagnostics come back sorted by
/// (site pc, rule id), program-level findings last.
pub fn run(
    image: &Image,
    cfg: &AnalyzeConfig,
    walk: &Walk,
    graph: &CallGraph,
) -> Vec<Diagnostic> {
    let mut out: Vec<Diagnostic> = Vec::new();

    // per-instruction rules over resolved accesses and CSR sites
    for (&pc, &instr) in &walk.instrs {
        let state = &walk.states[&pc];

        if let Some((addr, size, is_store)) = access_addr(instr, state) {
            let what = if is_store { "store" } else { "load" };
            if addr % size != 0 {
                push(
                    &mut out,
                    A002,
                    Some(pc),
                    format!("misaligned {size}-byte {what} at address {addr:#010x}"),
                );
            }
            match cfg.map.region(addr) {
                Region::Sram | Region::Bridge => {}
                Region::Unmapped => push(
                    &mut out,
                    A001,
                    Some(pc),
                    format!("{what} targets unmapped address {addr:#010x}"),
                ),
                Region::Periph => {
                    if periph_device(addr).is_none() {
                        push(
                            &mut out,
                            A001,
                            Some(pc),
                            format!(
                                "{what} targets unimplemented peripheral window {addr:#010x}"
                            ),
                        );
                    } else if size != 4 {
                        push(
                            &mut out,
                            A001,
                            Some(pc),
                            format!(
                                "{size}-byte {what} at {addr:#010x}: peripheral registers \
                                 are word-only"
                            ),
                        );
                    }
                }
            }

            // SMC candidate: the store's generation-page range overlaps a
            // text page (pages are global: SRAM starts at address 0, so
            // `addr >> GEN_PAGE_SHIFT` is the page id the backend tracks)
            if is_store {
                if let Some((t0, t1)) = image.text_extent {
                    if t1 > t0 {
                        let (s_lo, s_hi) =
                            (addr >> GEN_PAGE_SHIFT, (addr + size - 1) >> GEN_PAGE_SHIFT);
                        let (t_lo, t_hi) =
                            (t0 >> GEN_PAGE_SHIFT, (t1 - 1) >> GEN_PAGE_SHIFT);
                        if s_lo <= t_hi && s_hi >= t_lo {
                            push(
                                &mut out,
                                A003,
                                Some(pc),
                                format!(
                                    "store to {addr:#010x} hits a text page \
                                     (text {t0:#010x}..{t1:#010x}); the blocks backend \
                                     will invalidate and recompile"
                                ),
                            );
                        }
                    }
                }
            }
        }

        if let Instr::Csr { op, rs1, csr, imm } = instr {
            if !Csrs::is_known(csr) {
                push(
                    &mut out,
                    A005,
                    Some(pc),
                    format!("access to unimplemented CSR {csr:#05x} (traps at runtime)"),
                );
            } else {
                // a csrrs/csrrc with source x0 (or zimm 0) reads without
                // writing; everything else writes
                let writes = op == CsrOp::Rw || rs1 != 0;
                let _ = imm; // zimm shares the rs1 field, same writes rule
                if writes && Csrs::is_read_only(csr) {
                    push(
                        &mut out,
                        A005,
                        Some(pc),
                        format!("write to read-only CSR {csr:#05x} (traps at runtime)"),
                    );
                }
            }
        }
    }

    // control flow that leaves the executable world
    for &(site, target, kind) in &walk.bad_flow {
        match kind {
            FlowKind::OutsideSram => push(
                &mut out,
                A001,
                Some(site),
                format!(
                    "control flow to {target:#010x} ({}); only SRAM is executable",
                    cfg.map.region(target).name()
                ),
            ),
            FlowKind::Misaligned => push(
                &mut out,
                A002,
                Some(site),
                format!("control flow to misaligned target {target:#010x}"),
            ),
            FlowKind::Undecodable => push(
                &mut out,
                A001,
                Some(site),
                format!("control flow to {target:#010x}, which holds no decodable \
                         instruction"),
            ),
        }
    }

    // unresolved indirect jumps
    for &pc in &walk.unresolved {
        push(
            &mut out,
            A007,
            Some(pc),
            "indirect jump base is not statically resolvable; CFG and WCET are \
             incomplete past this point"
                .to_string(),
        );
    }

    // unreachable text: contiguous runs of text words the walk never saw
    if let Some((t0, t1)) = image.text_extent {
        let mut run_start: Option<u32> = None;
        let mut pc = t0;
        while pc < t1 {
            let reachable = walk.instrs.contains_key(&pc);
            match (reachable, run_start) {
                (false, None) => run_start = Some(pc),
                (true, Some(start)) => {
                    push(
                        &mut out,
                        A004,
                        Some(start),
                        format!(
                            "{} text byte(s) at {start:#010x}..{pc:#010x} are unreachable \
                             from the entry point",
                            pc - start
                        ),
                    );
                    run_start = None;
                }
                _ => {}
            }
            pc += 4;
        }
        if let Some(start) = run_start {
            push(
                &mut out,
                A004,
                Some(start),
                format!(
                    "{} text byte(s) at {start:#010x}..{t1:#010x} are unreachable from \
                     the entry point",
                    t1 - start
                ),
            );
        }
    }

    // call depth / recursion (program-level)
    if graph.recursive {
        push(
            &mut out,
            A006,
            None,
            "recursive call cycle is statically reachable; stack depth is unbounded"
                .to_string(),
        );
    } else if graph.max_depth > cfg.max_call_depth {
        push(
            &mut out,
            A006,
            None,
            format!(
                "static call depth {} exceeds the configured limit {}",
                graph.max_depth, cfg.max_call_depth
            ),
        );
    }

    out.sort_by_key(|d| (d.pc.map_or(u32::MAX, |pc| pc), d.rule));
    out
}

#[cfg(test)]
mod tests {
    use super::super::{analyze_program, AnalyzeConfig};
    use super::*;
    use crate::isa::assemble;

    fn diags(src: &str) -> Vec<Diagnostic> {
        let prog = assemble(src).unwrap();
        analyze_program(&prog, "lint-test", &AnalyzeConfig::default()).diagnostics
    }

    fn has(ds: &[Diagnostic], rule: &str) -> bool {
        ds.iter().any(|d| d.rule == rule)
    }

    #[test]
    fn catalog_ids_unique_and_ordered() {
        for w in CATALOG.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
    }

    #[test]
    fn a001_unmapped_and_unknown_periph() {
        let ds = diags(
            r#"
            _start:
                li t0, 0x30000000
                lw t1, 0(t0)
                ebreak
            "#,
        );
        assert!(has(&ds, A001), "{ds:?}");

        let ds = diags(
            r#"
            _start:
                li t0, 0x20000900
                sw zero, 0(t0)
                ebreak
            "#,
        );
        assert!(has(&ds, A001), "{ds:?}");

        // sub-word peripheral access is also a map violation
        let ds = diags(
            r#"
            _start:
                li t0, 0x20000100
                lb t1, 0(t0)
                ebreak
            "#,
        );
        assert!(has(&ds, A001), "{ds:?}");
    }

    #[test]
    fn a002_misaligned_access() {
        let ds = diags(
            r#"
            _start:
                li t0, 0x102
                lw t1, 0(t0)
                ebreak
            "#,
        );
        assert!(has(&ds, A002), "{ds:?}");
    }

    #[test]
    fn a003_store_into_text_page() {
        let ds = diags(
            r#"
            _start:
                la t0, _start
                sw zero, 0(t0)
                ebreak
            "#,
        );
        assert!(has(&ds, A003), "{ds:?}");
    }

    #[test]
    fn a004_unreachable_text() {
        let ds = diags(
            r#"
            _start:
                ebreak
            dead:
                addi a0, a0, 1
                ebreak
            "#,
        );
        assert!(has(&ds, A004), "{ds:?}");
    }

    #[test]
    fn a005_csr_rules() {
        // unknown CSR
        let ds = diags("_start: csrr t0, 0x7C0\nebreak");
        assert!(has(&ds, A005), "{ds:?}");
        // write to read-only mcycle
        let ds = diags("_start: csrw mcycle, t0\nebreak");
        assert!(has(&ds, A005), "{ds:?}");
        // reading a read-only counter is fine
        let ds = diags("_start: csrr t0, mcycle\nebreak");
        assert!(!has(&ds, A005), "{ds:?}");
        // mip is writable-but-ignored, not read-only
        let ds = diags("_start: csrw mip, t0\nebreak");
        assert!(!has(&ds, A005), "{ds:?}");
    }

    #[test]
    fn a007_unresolved_indirect() {
        let ds = diags(
            r#"
            _start:
                lw t0, 0(zero)
                jr t0
            "#,
        );
        assert!(has(&ds, A007), "{ds:?}");
    }

    #[test]
    fn clean_program_stays_clean() {
        let ds = diags(
            r#"
            _start:
                li t0, 0x20000100
                li t1, 1
                sw t1, 0(t0)
                ebreak
            "#,
        );
        assert!(ds.is_empty(), "{ds:?}");
    }
}
