//! Energy estimation: per-domain, per-power-state average-power models.
//!
//! Paper §IV-D: "an energy model is derived from a TSMC 65 nm CMOS
//! silicon implementation of X-HEEP, called HEEPocrates, and specifies
//! the average power consumption of each domain in its four power states
//! ... Energy consumption is calculated by multiplying the average power
//! values by the time spent in each state, as measured by the performance
//! counters."
//!
//! Two calibrations ship with the emulator (DESIGN.md §2 substitution):
//!
//! * [`EnergyModel::heepocrates`] — plays the role of the silicon
//!   measurements (the "chip" series of Figs 4/5);
//! * [`EnergyModel::femu`] — the FEMU-side estimate, with the paper's
//!   reported deviations baked in: ≈5 % on the CPU-domain numbers (the
//!   simplified model) and ≈20 % on the CGRA (post-place-and-route
//!   power, less accurate than silicon).
//!
//! Custom calibrations load from TOML (`configs/energy/*.toml`) via
//! [`crate::config`].

use std::collections::BTreeMap;

use crate::perfmon::{Domain, PerfSnapshot, PowerState};

/// Average power of one domain in each of the four states, in milliwatts.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DomainPower {
    /// mW in Active / ClockGated / PowerGated / Retention.
    pub mw: [f64; 4],
}

impl DomainPower {
    pub fn new(active: f64, clock_gated: f64, power_gated: f64, retention: f64) -> Self {
        Self { mw: [active, clock_gated, power_gated, retention] }
    }

    pub fn get(&self, s: PowerState) -> f64 {
        self.mw[s as usize]
    }

    fn scaled(self, factor: f64) -> Self {
        Self { mw: self.mw.map(|p| p * factor) }
    }
}

/// A full platform calibration.
#[derive(Clone, Debug)]
pub struct EnergyModel {
    pub name: String,
    pub cpu: DomainPower,
    pub bus: DomainPower,
    pub periph: DomainPower,
    /// Per-bank power (all banks identical in both calibrations).
    pub mem_bank: DomainPower,
    pub cgra: DomainPower,
    /// Clock this calibration is valid at (power scales with f; we only
    /// evaluate at the calibration point, like the paper does at 20 MHz).
    pub freq_hz: u64,
}

impl EnergyModel {
    /// The "silicon" calibration (HEEPocrates at 20 MHz, 0.8 V). Values
    /// are in the published ballpark for a 65 nm ULP RISC-V MCU: a few mW
    /// active, tens of µW gated, µW-scale retention/off.
    pub fn heepocrates() -> Self {
        Self {
            name: "heepocrates".into(),
            cpu: DomainPower::new(1.90, 0.210, 0.012, 0.0),
            bus: DomainPower::new(0.74, 0.092, 0.008, 0.0),
            periph: DomainPower::new(0.58, 0.064, 0.006, 0.0),
            mem_bank: DomainPower::new(0.42, 0.048, 0.004, 0.021),
            cgra: DomainPower::new(2.60, 0.230, 0.015, 0.0),
            freq_hz: 20_000_000,
        }
    }

    /// The FEMU-side estimate: the same structure with the deviations the
    /// paper reports for its simplified model — ≈5 % on the host domains
    /// (silicon-derived averages applied to emulated state residencies)
    /// and ≈20 % on the CGRA (post-PnR numbers).
    pub fn femu() -> Self {
        let chip = Self::heepocrates();
        Self {
            name: "femu".into(),
            cpu: chip.cpu.scaled(1.05),
            bus: chip.bus.scaled(0.95),
            periph: chip.periph.scaled(1.04),
            mem_bank: chip.mem_bank.scaled(1.06),
            cgra: chip.cgra.scaled(1.20),
            freq_hz: chip.freq_hz,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "heepocrates" => Some(Self::heepocrates()),
            "femu" => Some(Self::femu()),
            _ => None,
        }
    }

    fn domain_power(&self, d: Domain) -> DomainPower {
        match d {
            Domain::Cpu => self.cpu,
            Domain::Bus => self.bus,
            Domain::Periph => self.periph,
            Domain::MemBank(_) => self.mem_bank,
            Domain::Cgra => self.cgra,
        }
    }

    /// Energy of one domain over a counter snapshot, in millijoules.
    pub fn domain_energy_mj(&self, d: Domain, counts: &crate::perfmon::StateCycles) -> f64 {
        let p = self.domain_power(d);
        PowerState::ALL
            .iter()
            .map(|&s| p.get(s) * counts.get(s) as f64 / self.freq_hz as f64)
            .sum()
    }

    /// Full estimate over a perf snapshot.
    pub fn estimate(&self, snap: &PerfSnapshot) -> EnergyReport {
        let mut per_domain = BTreeMap::new();
        let mut total = 0.0;
        for (d, counts) in snap.domains() {
            let e = self.domain_energy_mj(d, &counts);
            per_domain.insert(d.to_string(), e);
            total += e;
        }
        // active vs sleep split (Fig 4): "active" energy = energy accrued
        // in Active states; "sleep" = everything else.
        let mut active = 0.0;
        for (d, counts) in snap.domains() {
            let p = self.domain_power(d);
            active += p.get(PowerState::Active) * counts.get(PowerState::Active) as f64
                / self.freq_hz as f64;
        }
        EnergyReport {
            model: self.name.clone(),
            total_mj: total,
            active_mj: active,
            sleep_mj: total - active,
            per_domain_mj: per_domain,
            cycles: snap.cycles,
            freq_hz: self.freq_hz,
        }
    }

    /// Estimate over the window between two snapshots of the same
    /// monitor (`before` taken earlier): prices the counter delta like
    /// [`EnergyModel::estimate`]. The profiler reads its windows
    /// through this.
    pub fn estimate_window(&self, before: &PerfSnapshot, after: &PerfSnapshot) -> EnergyReport {
        self.estimate(&after.delta(before))
    }

    /// Platform power with *every* domain Active, in mW — the ceiling no
    /// residency split can exceed, since Active is the most expensive
    /// state in both calibrations.
    pub fn active_power_mw(&self, num_banks: usize) -> f64 {
        self.cpu.get(PowerState::Active)
            + self.bus.get(PowerState::Active)
            + self.periph.get(PowerState::Active)
            + num_banks as f64 * self.mem_bank.get(PowerState::Active)
            + self.cgra.get(PowerState::Active)
    }

    /// Static worst-case energy for a run of at most `cycles` cycles:
    /// all domains Active the whole time. For any real run of `c <=
    /// cycles` cycles, `estimate()` ≤ this bound — the analyzer's
    /// bounds-vs-reality tests assert it ([`crate::analyze`]).
    pub fn bound_mj(&self, cycles: u64, num_banks: usize) -> f64 {
        self.active_power_mw(num_banks) * cycles as f64 / self.freq_hz as f64
    }
}

/// The output of an estimation pass.
#[derive(Clone, Debug)]
pub struct EnergyReport {
    pub model: String,
    pub total_mj: f64,
    /// Energy accrued while domains were Active (Fig 4's "active" bars).
    pub active_mj: f64,
    /// Energy accrued in gated/retention states (Fig 4's "sleep" bars).
    pub sleep_mj: f64,
    pub per_domain_mj: BTreeMap<String, f64>,
    pub cycles: u64,
    pub freq_hz: u64,
}

impl EnergyReport {
    pub fn seconds(&self) -> f64 {
        self.cycles as f64 / self.freq_hz as f64
    }

    /// Average power in mW over the window.
    pub fn avg_power_mw(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.total_mj / self.seconds()
        }
    }
}

/// Relative deviation |a-b| / max(|b|, eps) — used for the FEMU-vs-chip
/// validation numbers (§V-B: ~5 % CPU-only, ~20 % CGRA).
pub fn relative_deviation(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmon::{PerfMonitor, PowerState};

    fn snapshot_active_for(cycles: u64, banks: usize) -> PerfSnapshot {
        let pm = PerfMonitor::new(banks);
        pm.snapshot(cycles)
    }

    #[test]
    fn all_active_energy_matches_hand_calc() {
        let m = EnergyModel::heepocrates();
        // 20e6 cycles at 20 MHz = 1 s, everything Active except CGRA
        // (PerfMonitor starts CGRA power-gated).
        let snap = snapshot_active_for(20_000_000, 2);
        let r = m.estimate(&snap);
        let expect =
            1.90 + 0.74 + 0.58 + 2.0 * 0.42 + 0.015 /* cgra power-gated 1s */;
        assert!((r.total_mj - expect).abs() < 1e-9, "{} vs {expect}", r.total_mj);
        assert!((r.avg_power_mw() - expect).abs() < 1e-9);
    }

    #[test]
    fn sleep_dominated_split() {
        let mut pm = PerfMonitor::new(1);
        // active 1k cycles, then clock-gated 999k cycles
        pm.set_state(Domain::Cpu, PowerState::ClockGated, 1_000);
        pm.set_state(Domain::Bus, PowerState::ClockGated, 1_000);
        pm.set_state(Domain::Periph, PowerState::ClockGated, 1_000);
        pm.set_state(Domain::MemBank(0), PowerState::Retention, 1_000);
        let snap = pm.snapshot(1_000_000);
        let r = EnergyModel::heepocrates().estimate(&snap);
        assert!(r.sleep_mj > 0.0 && r.active_mj > 0.0);
        // active share of *time* is 0.1%; active energy share is larger
        // (active power >> sleep power) but still well under 50%
        assert!(r.active_mj / r.total_mj < 0.5, "{}", r.active_mj / r.total_mj);
    }

    #[test]
    fn femu_vs_chip_deviation_bands() {
        // CPU-only workload: deviation should be ~5%; CGRA-dominated: ~20%.
        let snap = snapshot_active_for(1_000_000, 2);
        let chip = EnergyModel::heepocrates().estimate(&snap);
        let femu = EnergyModel::femu().estimate(&snap);
        let dev = relative_deviation(femu.total_mj, chip.total_mj);
        assert!(dev > 0.01 && dev < 0.10, "cpu-only deviation {dev}");

        let mut pm = PerfMonitor::new(2);
        pm.set_state(Domain::Cgra, PowerState::Active, 0);
        let snap = pm.snapshot(1_000_000);
        let chip_e = EnergyModel::heepocrates().domain_energy_mj(Domain::Cgra, &snap.cgra);
        let femu_e = EnergyModel::femu().domain_energy_mj(Domain::Cgra, &snap.cgra);
        let dev = relative_deviation(femu_e, chip_e);
        assert!((dev - 0.20).abs() < 0.01, "cgra deviation {dev}");
    }

    #[test]
    fn per_domain_report_keys() {
        let snap = snapshot_active_for(100, 3);
        let r = EnergyModel::femu().estimate(&snap);
        let keys: Vec<_> = r.per_domain_mj.keys().cloned().collect();
        assert!(keys.contains(&"cpu".to_string()));
        assert!(keys.contains(&"mem_bank2".to_string()));
        assert!(keys.contains(&"cgra".to_string()));
        let sum: f64 = r.per_domain_mj.values().sum();
        assert!((sum - r.total_mj).abs() < 1e-12);
    }

    #[test]
    fn static_bound_dominates_any_estimate() {
        // all-active is the worst case: any residency split at or under
        // the cycle bound estimates at or under bound_mj
        let m = EnergyModel::femu();
        let snap = snapshot_active_for(1_000_000, 2);
        let measured = m.estimate(&snap).total_mj;
        let bound = m.bound_mj(1_000_000, 2);
        assert!(bound >= measured, "{bound} < {measured}");

        let mut pm = PerfMonitor::new(2);
        pm.set_state(Domain::Cpu, PowerState::ClockGated, 500);
        let sleepy = m.estimate(&pm.snapshot(1_000_000)).total_mj;
        assert!(bound >= sleepy);
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(EnergyModel::by_name("femu").unwrap().name, "femu");
        assert!(EnergyModel::by_name("nope").is_none());
    }
}
