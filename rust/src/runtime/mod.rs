//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only place the emulator touches XLA. `python/compile/aot.py`
//! lowers the L2 JAX entry points (which call the L1 Pallas kernels with
//! `interpret=True`) to HLO *text* once at build time; at emulation time the
//! CS accelerator-virtualization service ([`crate::virt::accel`]) executes
//! them through [`Runtime`]. Python never runs on the emulation path.
//!
//! Interchange contract (see DESIGN.md §3 and artifacts/manifest.json):
//! HLO text (not serialized protos — xla_extension 0.5.1 rejects jax>=0.5's
//! 64-bit instruction ids), `return_tuple=True` so every result is a tuple.

mod artifacts;
mod tensor;

pub use artifacts::{ArtifactEntry, ArtifactManifest, TensorSpec};
pub use tensor::TensorI32;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// A PJRT CPU client plus the compiled executables for every artifact
/// entry listed in `manifest.json`.
///
/// Compilation happens once at load; execution is reentrant and allocation
/// is limited to the operand/result literals.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl Runtime {
    /// Load every artifact in `dir` (expects `manifest.json` plus the
    /// `*.hlo.txt` files it references) and compile them on the PJRT CPU
    /// client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = ArtifactManifest::load(dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        let mut executables = HashMap::new();
        for (name, entry) in &manifest.entries {
            let path = dir.join(&entry.file);
            let exe = Self::compile_one(&client, &path)
                .with_context(|| format!("compiling artifact `{name}` from {path:?}"))?;
            executables.insert(name.clone(), exe);
        }
        Ok(Self { client, manifest, executables, dir })
    }

    /// Test/bench gate for artifact-backed paths: load the artifacts in
    /// `dir`, returning `None` with a skip notice when they (or the PJRT
    /// backend) are unavailable — offline checkouts have neither (see
    /// vendor/xla/README.md). Setting `FEMU_REQUIRE_ARTIFACTS` turns the
    /// skip into a hard failure, so full environments keep a regression
    /// signal instead of silently going green on a broken loader.
    pub fn load_or_skip(dir: impl AsRef<Path>, what: &str) -> Option<Self> {
        match Self::load(dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                if std::env::var_os("FEMU_REQUIRE_ARTIFACTS").is_some() {
                    panic!("FEMU_REQUIRE_ARTIFACTS is set but {what} cannot load: {e:#}");
                }
                eprintln!("skipping {what} (artifacts unavailable: {e:#})");
                None
            }
        }
    }

    /// Load a single extra HLO-text computation not listed in the manifest
    /// (used by tests and by user-supplied accelerator models).
    pub fn load_extra(&mut self, name: &str, hlo_path: impl AsRef<Path>) -> Result<()> {
        let exe = Self::compile_one(&self.client, hlo_path.as_ref())?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    fn compile_one(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let path_str =
            path.to_str().ok_or_else(|| anyhow!("non-utf8 artifact path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow!("parse HLO text {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client.compile(&comp).map_err(|e| anyhow!("XLA compile {path:?}: {e}"))
    }

    /// Names of all loaded entry points.
    pub fn entry_names(&self) -> Vec<&str> {
        self.manifest.entries.keys().map(|s| s.as_str()).collect()
    }

    /// The manifest the artifacts were loaded from.
    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Directory the artifacts were loaded from.
    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// Execute entry `name` with int32 tensor operands, returning the
    /// int32 tensor results (the result tuple, flattened).
    ///
    /// Operand shapes are validated against the manifest before execution
    /// so shape bugs surface as errors here, not as XLA aborts.
    pub fn execute(&self, name: &str, inputs: &[TensorI32]) -> Result<Vec<TensorI32>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact entry `{name}`"))?;
        if let Some(entry) = self.manifest.entries.get(name) {
            entry.validate_args(inputs)?;
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(TensorI32::to_literal).collect::<Result<_>>()?;
        let result =
            exe.execute::<xla::Literal>(&literals).map_err(|e| anyhow!("execute `{name}`: {e}"))?;
        let first = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("execute `{name}`: empty result"))?;
        let literal =
            first.to_literal_sync().map_err(|e| anyhow!("fetch result of `{name}`: {e}"))?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let parts =
            literal.to_tuple().map_err(|e| anyhow!("untuple result of `{name}`: {e}"))?;
        let specs = self.manifest.entries.get(name).map(|e| e.results.as_slice());
        let mut out = Vec::with_capacity(parts.len());
        for (i, part) in parts.into_iter().enumerate() {
            let shape = match specs.and_then(|s| s.get(i)) {
                Some(spec) => spec.shape.clone(),
                None => vec![part.element_count()],
            };
            out.push(TensorI32::from_literal(&part, shape)?);
        }
        if let Some(specs) = specs {
            if out.len() != specs.len() {
                bail!(
                    "entry `{name}`: manifest promises {} results, got {}",
                    specs.len(),
                    out.len()
                );
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn runtime() -> Option<Runtime> {
        Runtime::load_or_skip(artifact_dir(), "runtime test")
    }

    #[test]
    fn load_and_list_entries() {
        let Some(rt) = runtime() else { return };
        let mut names = rt.entry_names();
        names.sort();
        assert_eq!(names, vec!["conv2d", "fft512", "matmul", "model"]);
    }

    #[test]
    fn matmul_identity_roundtrip() {
        let Some(rt) = runtime() else { return };
        // B = 16x4 "identity-ish": first 4 rows identity, rest zero, so
        // C[:, j] = A[:, j] for j < 4.
        let a = TensorI32::from_fn(vec![121, 16], |idx| (idx[0] * 16 + idx[1]) as i32);
        let mut b = TensorI32::zeros(vec![16, 4]);
        for j in 0..4 {
            b.set(&[j, j], 1);
        }
        let out = rt.execute("matmul", &[a.clone(), b]).unwrap();
        assert_eq!(out.len(), 1);
        let c = &out[0];
        assert_eq!(c.shape(), &[121, 4]);
        for i in 0..121 {
            for j in 0..4 {
                assert_eq!(c.get(&[i, j]), a.get(&[i, j]));
            }
        }
    }

    #[test]
    fn execute_rejects_bad_shape() {
        let Some(rt) = runtime() else { return };
        let a = TensorI32::zeros(vec![2, 2]);
        let b = TensorI32::zeros(vec![16, 4]);
        assert!(rt.execute("matmul", &[a, b]).is_err());
    }

    #[test]
    fn execute_rejects_unknown_entry() {
        let Some(rt) = runtime() else { return };
        assert!(rt.execute("nope", &[]).is_err());
    }
}
