//! Dense int32 tensors — the operand/result type of the PJRT runtime and
//! the payload format of the accelerator-virtualization mailbox.
//!
//! Row-major (C order), matching both JAX's default layout and the flat
//! little-endian word layout the RV32 guest uses in mailbox DRAM, so a
//! mailbox region can be reinterpreted as a tensor without copying or
//! reordering.

use anyhow::{anyhow, bail, Result};

/// A dense, row-major int32 tensor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorI32 {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl TensorI32 {
    /// Build from shape + data; `data.len()` must equal the shape product.
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(Self { shape, data })
    }

    /// All-zeros tensor.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0; n] }
    }

    /// Build element-wise from a function of the multi-index.
    pub fn from_fn(shape: Vec<usize>, mut f: impl FnMut(&[usize]) -> i32) -> Self {
        let n: usize = shape.iter().product();
        let mut data = Vec::with_capacity(n);
        let mut idx = vec![0usize; shape.len()];
        for _ in 0..n {
            data.push(f(&idx));
            // increment the multi-index, last axis fastest (row-major)
            for ax in (0..shape.len()).rev() {
                idx[ax] += 1;
                if idx[ax] < shape[ax] {
                    break;
                }
                idx[ax] = 0;
            }
        }
        Self { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[i32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [i32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<i32> {
        self.data
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    fn flat_index(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut flat = 0usize;
        for (i, (&x, &dim)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(x < dim, "index {idx:?} out of bounds at axis {i}");
            flat = flat * dim + x;
        }
        flat
    }

    pub fn get(&self, idx: &[usize]) -> i32 {
        self.data[self.flat_index(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: i32) {
        let i = self.flat_index(idx);
        self.data[i] = v;
    }

    /// Convert to an XLA literal with this tensor's shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(&self.data)
            .reshape(&dims)
            .map_err(|e| anyhow!("literal reshape to {dims:?}: {e}"))
    }

    /// Read an XLA literal back into a tensor, trusting `shape` from the
    /// manifest (the literal itself only knows its element count here).
    pub fn from_literal(lit: &xla::Literal, shape: Vec<usize>) -> Result<Self> {
        let data = lit.to_vec::<i32>().map_err(|e| anyhow!("literal to_vec<i32>: {e}"))?;
        Self::new(shape, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_element_count() {
        assert!(TensorI32::new(vec![2, 3], vec![0; 6]).is_ok());
        assert!(TensorI32::new(vec![2, 3], vec![0; 5]).is_err());
    }

    #[test]
    fn from_fn_row_major_order() {
        let t = TensorI32::from_fn(vec![2, 3], |i| (i[0] * 10 + i[1]) as i32);
        assert_eq!(t.data(), &[0, 1, 2, 10, 11, 12]);
        assert_eq!(t.get(&[1, 2]), 12);
    }

    #[test]
    fn set_get_roundtrip() {
        let mut t = TensorI32::zeros(vec![3, 3]);
        t.set(&[2, 1], -7);
        assert_eq!(t.get(&[2, 1]), -7);
        assert_eq!(t.data()[7], -7);
    }

    #[test]
    fn scalar_shape() {
        let t = TensorI32::from_fn(vec![], |_| 42);
        assert_eq!(t.len(), 1);
        assert_eq!(t.get(&[]), 42);
    }
}
