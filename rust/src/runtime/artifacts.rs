//! Artifact manifest: the AOT interchange contract with `python/compile/aot.py`.
//!
//! `manifest.json` describes, for every lowered entry point, the operand
//! and result tensor specs. The runtime validates operands against it and
//! uses the result specs to reshape execution outputs.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::Json;

use super::TensorI32;

/// Shape + dtype of one tensor operand/result.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(v: &Json) -> Result<Self> {
        let shape = v
            .get("shape")?
            .as_arr()?
            .iter()
            .map(Json::as_usize)
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { shape, dtype: v.str_field("dtype")?.to_string() })
    }
}

/// One AOT entry point (one `.hlo.txt` file).
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub file: String,
    pub args: Vec<TensorSpec>,
    pub results: Vec<TensorSpec>,
}

impl ArtifactEntry {
    fn from_json(v: &Json) -> Result<Self> {
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            v.get(key)?.as_arr()?.iter().map(TensorSpec::from_json).collect()
        };
        Ok(Self {
            file: v.str_field("file")?.to_string(),
            args: specs("args")?,
            results: specs("results")?,
        })
    }

    /// Check operand count/shapes/dtypes against the manifest spec.
    pub fn validate_args(&self, inputs: &[TensorI32]) -> Result<()> {
        if inputs.len() != self.args.len() {
            bail!("expected {} operands, got {}", self.args.len(), inputs.len());
        }
        for (i, (spec, t)) in self.args.iter().zip(inputs).enumerate() {
            if spec.dtype != "int32" {
                bail!("operand {i}: manifest dtype {} unsupported (int32 only)", spec.dtype);
            }
            if t.shape() != spec.shape.as_slice() {
                bail!("operand {i}: expected shape {:?}, got {:?}", spec.shape, t.shape());
            }
        }
        Ok(())
    }
}

/// The whole manifest (BTreeMap for deterministic iteration order).
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub format: String,
    pub return_tuple: bool,
    pub entries: BTreeMap<String, ArtifactEntry>,
}

impl ArtifactManifest {
    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let format = v.str_field("format")?.to_string();
        if format != "hlo-text" {
            bail!("manifest format `{format}` unsupported (want hlo-text)");
        }
        let return_tuple = v.get("return_tuple")?.as_bool()?;
        if !return_tuple {
            bail!("manifest must be lowered with return_tuple=True");
        }
        let mut entries = BTreeMap::new();
        for (name, e) in v.get("entries")?.as_obj()? {
            entries.insert(
                name.clone(),
                ArtifactEntry::from_json(e).with_context(|| format!("entry `{name}`"))?,
            );
        }
        Ok(Self { format, return_tuple, entries })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {path:?} (run `make artifacts`?)"))?;
        Self::parse(&text).with_context(|| format!("parsing manifest {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry() -> ArtifactEntry {
        ArtifactEntry {
            file: "x.hlo.txt".into(),
            args: vec![
                TensorSpec { shape: vec![2, 3], dtype: "int32".into() },
                TensorSpec { shape: vec![3], dtype: "int32".into() },
            ],
            results: vec![TensorSpec { shape: vec![2], dtype: "int32".into() }],
        }
    }

    #[test]
    fn validate_accepts_matching_args() {
        let e = entry();
        let ok = [TensorI32::zeros(vec![2, 3]), TensorI32::zeros(vec![3])];
        assert!(e.validate_args(&ok).is_ok());
    }

    #[test]
    fn validate_rejects_wrong_count_and_shape() {
        let e = entry();
        assert!(e.validate_args(&[TensorI32::zeros(vec![2, 3])]).is_err());
        let bad = [TensorI32::zeros(vec![3, 2]), TensorI32::zeros(vec![3])];
        assert!(e.validate_args(&bad).is_err());
    }

    #[test]
    fn manifest_parses_and_checks_format() {
        let json = r#"{"format":"hlo-text","return_tuple":true,
            "entries":{"e":{"file":"e.hlo.txt","args":[],"results":[]}}}"#;
        let m = ArtifactManifest::parse(json).unwrap();
        assert!(m.return_tuple);
        assert_eq!(m.entries.len(), 1);
        assert_eq!(m.entries["e"].file, "e.hlo.txt");
    }

    #[test]
    fn manifest_rejects_wrong_format() {
        let json = r#"{"format":"proto","return_tuple":true,"entries":{}}"#;
        assert!(ArtifactManifest::parse(json).is_err());
        let json2 = r#"{"format":"hlo-text","return_tuple":false,"entries":{}}"#;
        assert!(ArtifactManifest::parse(json2).is_err());
    }
}
