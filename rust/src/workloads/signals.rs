//! Synthetic signal/dataset generators.
//!
//! Substitutes for the paper's pre-recorded datasets (bio-signals on the
//! SD card, ultrasound wood-moisture windows): deterministic synthetic
//! signals with the same shape — a seeded mixture of sinusoids plus
//! noise, quantized to 16-bit ADC codes. Determinism (seeded SplitMix64)
//! makes every experiment in EXPERIMENTS.md exactly reproducible.

use crate::util::Rng;

/// A synthetic "recorded" signal: 16-bit ADC codes stored as i32 (the
/// ADC virtualization streams one word per sample).
#[derive(Clone, Debug)]
pub struct Signal {
    pub samples: Vec<i32>,
    pub sample_rate_hz: f64,
}

/// Generate a bio-like signal: sum of sinusoids with drift and noise,
/// clipped to 16-bit signed codes.
pub fn biosignal(seed: u64, n: usize, sample_rate_hz: f64) -> Signal {
    let mut rng = Rng::new(seed);
    // a few component tones below Nyquist
    let tones: Vec<(f64, f64, f64)> = (0..3)
        .map(|_| {
            let freq = 0.5 + rng.f64() * (sample_rate_hz / 8.0);
            let amp = 2000.0 + rng.f64() * 8000.0;
            let phase = rng.f64() * std::f64::consts::TAU;
            (freq, amp, phase)
        })
        .collect();
    let samples = (0..n)
        .map(|i| {
            let t = i as f64 / sample_rate_hz;
            let mut v = 0.0;
            for &(f, a, p) in &tones {
                v += a * (std::f64::consts::TAU * f * t + p).sin();
            }
            // noise in ±256 codes
            v += (rng.f64() - 0.5) * 512.0;
            (v.clamp(-32768.0, 32767.0)) as i32
        })
        .collect();
    Signal { samples, sample_rate_hz }
}

/// Ultrasound-like burst windows for the §V-C wood-moisture case study:
/// `windows` windows of `window_len` 16-bit samples (the paper uses
/// 35 000 samples per window, 240 windows).
pub fn ultrasound_windows(seed: u64, windows: usize, window_len: usize) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    (0..windows)
        .map(|w| {
            let decay = 40.0 + rng.f64() * 200.0;
            let freq = 0.05 + rng.f64() * 0.2; // cycles per sample
            let amp = 8000.0 + rng.f64() * 16000.0;
            (0..window_len)
                .map(|i| {
                    let env = (-(i as f64) / decay).exp();
                    let v = amp * env * (std::f64::consts::TAU * freq * i as f64).sin()
                        + (rng.f64() - 0.5) * 128.0;
                    let _ = w;
                    v.clamp(-32768.0, 32767.0) as i32
                })
                .collect()
        })
        .collect()
}

/// Pack i32 samples as little-endian bytes (flash/DRAM image layout).
pub fn to_le_bytes(samples: &[i32]) -> Vec<u8> {
    samples.iter().flat_map(|s| s.to_le_bytes()).collect()
}

/// Pack 16-bit samples two-per-word (the §V-C flash image layout: 35 000
/// 16-bit samples per window = 70 KiB = 17 500 words).
pub fn pack_i16_pairs(samples: &[i32]) -> Vec<u8> {
    samples.iter().flat_map(|&s| (s as i16).to_le_bytes()).collect()
}

/// Deterministic int32 operand tensors for the Fig 5 kernels.
pub fn kernel_operands(seed: u64, n: usize, lo: i32, hi: i32) -> Vec<i32> {
    Rng::new(seed).vec_i32(n, lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn biosignal_deterministic_and_bounded() {
        let a = biosignal(42, 1000, 1000.0);
        let b = biosignal(42, 1000, 1000.0);
        assert_eq!(a.samples, b.samples);
        assert!(a.samples.iter().all(|&s| (-32768..=32767).contains(&s)));
        let c = biosignal(43, 1000, 1000.0);
        assert_ne!(a.samples, c.samples);
        // not degenerate
        let distinct: std::collections::HashSet<_> = a.samples.iter().collect();
        assert!(distinct.len() > 100);
    }

    #[test]
    fn ultrasound_window_shape() {
        let w = ultrasound_windows(7, 3, 500);
        assert_eq!(w.len(), 3);
        assert!(w.iter().all(|x| x.len() == 500));
        // bursts decay: early samples carry more energy than late ones
        let early: i64 = w[0][..50].iter().map(|&v| (v as i64).abs()).sum();
        let late: i64 = w[0][450..].iter().map(|&v| (v as i64).abs()).sum();
        assert!(early > late * 2, "early {early} late {late}");
    }

    #[test]
    fn byte_packing_roundtrip() {
        let s = vec![-1i32, 2, -3];
        let b = to_le_bytes(&s);
        assert_eq!(b.len(), 12);
        let back: Vec<i32> =
            b.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect();
        assert_eq!(back, s);
    }
}
