//! Guest RV32 assembly programs for the case studies.
//!
//! Each function renders a parameterized assembly program (assembled by
//! [`crate::isa::assemble`]) implementing one workload:
//!
//! * [`acquisition`] — §V-A: sample a window from the virtualized ADC,
//!   WFI-sleeping between samples (the active/sleep split of Fig 4);
//! * [`mm_cpu`] / [`conv_cpu`] / [`fft_cpu`] — Fig 5 CPU baselines;
//! * [`mm_cgra`] / [`conv_cgra`] / [`fft_cgra`] — Fig 5 CGRA drivers
//!   (configure the control port, launch, WFI until done);
//! * [`classifier_mailbox`] — §V-C-style end-to-end app: acquire a
//!   window, hand it to the *virtualized* accelerator via the mailbox,
//!   print the argmax class over UART.
//!
//! Data buffers live at fixed labels; the CS injects operands and reads
//! results through debugger virtualization ([`crate::virt::debugger`]).

/// Shared address-map prelude (matches `crate::bus` / `crate::periph`).
pub const PRELUDE: &str = r#"
.equ UART,     0x20000000
.equ GPIO,     0x20000100
.equ TIMER,    0x20000200
.equ SPI_ADC,  0x20000300
.equ SPI_FLASH,0x20000400
.equ DMA,      0x20000500
.equ POWER,    0x20000600
.equ CGRA,     0x20000700
.equ MBOX,     0x20000800
.equ BRIDGE,   0x40000000
.equ PERF_BIT, 0x10000
.equ MIE_ADC,  0x20000   # fast line 1 -> mie bit 17
.equ MIE_DMA,  0x40000   # fast line 2 -> mie bit 18
.equ MIE_CGRA, 0x80000   # fast line 3 -> mie bit 19
.equ MIE_MBOX, 0x100000  # fast line 4 -> mie bit 20
"#;

/// §V-A acquisition kernel: read `n_samples` from the virtualized ADC
/// into a circular buffer, sleeping (WFI) between samples. `sleep_mem`:
/// 0 = banks stay active, 1 = clock-gate, 2 = retention during sleep.
pub fn acquisition(n_samples: u64, sleep_mem: u32) -> String {
    format!(
        r#"{PRELUDE}
.equ NSAMPLES, {n_samples}
_start:
    li  s0, SPI_ADC
    li  s1, NSAMPLES
    la  s2, buf
    la  s4, buf_end
    li  s3, 0            # consumed
    li  t0, {sleep_mem}
    li  t1, POWER
    sw  t0, 0(t1)        # SLEEP_MEM_MODE
    li  t0, 3            # enable + irq
    sw  t0, 0(s0)
    li  t0, MIE_ADC      # ADC fast irq wakes WFI (no trap: MIE off)
    csrw mie, t0
loop:
    lw  t1, 4(s0)        # STATUS
    andi t2, t1, 1
    bnez t2, take
    wfi
    j   loop
take:
    lw  t3, 8(s0)        # RXDATA (costs the SPI word time)
    sw  t3, 0(s2)
    addi s2, s2, 4
    bltu s2, s4, nowrap
    la  s2, buf
nowrap:
    addi s3, s3, 1
    bltu s3, s1, loop
    ebreak
.data
buf:     .space 4096
buf_end: .word 0
"#
    )
}

/// Fig 5 MM CPU baseline: C(m x n) = A(m x k) @ B(k x n), INT32.
/// Operand/result buffers at `a_buf` / `b_buf` / `c_buf`.
pub fn mm_cpu(m: usize, k: usize, n: usize) -> String {
    format!(
        r#"{PRELUDE}
.equ M, {m}
.equ K, {k}
.equ N, {n}
.equ NB, {nb}       # N*4
.equ KB, {kb}       # K*4
_start:
    li  t0, GPIO
    li  t1, PERF_BIT
    sw  t1, 0(t0)        # open manual perf window
    la  s0, a_buf        # A row ptr
    la  s2, c_buf
    li  s3, M
i_loop:
    la  s1, b_buf
    li  s4, N
j_loop:
    mv  t0, s0
    mv  t1, s1
    li  t2, K
    li  t3, 0
k_loop:
    lw  t4, 0(t0)
    lw  t5, 0(t1)
    mul t6, t4, t5
    add t3, t3, t6
    addi t0, t0, 4
    addi t1, t1, NB
    addi t2, t2, -1
    bnez t2, k_loop
    sw  t3, 0(s2)
    addi s2, s2, 4
    addi s1, s1, 4
    addi s4, s4, -1
    bnez s4, j_loop
    addi s0, s0, KB
    addi s3, s3, -1
    bnez s3, i_loop
    li  t0, GPIO
    sw  zero, 0(t0)      # close perf window
    ebreak
.data
a_buf: .space {a_bytes}
b_buf: .space {b_bytes}
c_buf: .space {c_bytes}
"#,
        nb = n * 4,
        kb = k * 4,
        a_bytes = m * k * 4,
        b_bytes = k * n * 4,
        c_bytes = m * n * 4,
    )
}

/// Fig 5 CONV CPU baseline: valid conv2d, x (h,w,cin) HWC, wts
/// (f,kh,kw,cin), y (oh,ow,f). Buffers at `x_buf` / `w_buf` / `y_buf`.
pub fn conv_cpu(h: usize, w: usize, cin: usize, f: usize, kh: usize, kw: usize) -> String {
    let oh = h - kh + 1;
    let ow = w - kw + 1;
    format!(
        r#"{PRELUDE}
.equ OH, {oh}
.equ OW, {ow}
.equ F, {f}
.equ KH, {kh}
.equ KWC, {kwc}       # KW*Cin (contiguous inner run)
.equ ROWSKIP, {rowskip}  # (W-KW)*Cin*4
.equ XSTEP, {xstep}   # Cin*4 (next ox)
.equ XADJ, {xadj}     # (W-OW)*Cin*4: rewind ox walk, advance one row
_start:
    li  t0, GPIO
    li  t1, PERF_BIT
    sw  t1, 0(t0)
    la  s0, x_buf        # x patch base (oy, ox)
    la  s2, y_buf
    li  s3, OH
oy_loop:
    li  s4, OW
ox_loop:
    la  s1, w_buf        # filter 0
    li  s5, F
f_loop:
    mv  t0, s0           # x ptr
    li  t3, 0            # acc
    li  s6, KH
di_loop:
    li  t2, KWC
ci_loop:
    lw  t4, 0(t0)
    lw  t5, 0(s1)
    mul t6, t4, t5
    add t3, t3, t6
    addi t0, t0, 4
    addi s1, s1, 4
    addi t2, t2, -1
    bnez t2, ci_loop
    addi t0, t0, ROWSKIP
    addi s6, s6, -1
    bnez s6, di_loop
    sw  t3, 0(s2)
    addi s2, s2, 4
    addi s5, s5, -1
    bnez s5, f_loop
    addi s0, s0, XSTEP
    addi s4, s4, -1
    bnez s4, ox_loop
    addi s0, s0, XADJ
    addi s3, s3, -1
    bnez s3, oy_loop
    li  t0, GPIO
    sw  zero, 0(t0)
    ebreak
.data
x_buf: .space {x_bytes}
w_buf: .space {w_bytes}
y_buf: .space {y_bytes}
"#,
        kwc = kw * cin,
        rowskip = (w - kw) * cin * 4,
        xstep = cin * 4,
        xadj = (w - ow) * cin * 4,
        x_bytes = h * w * cin * 4,
        w_bytes = f * kh * kw * cin * 4,
        y_bytes = oh * ow * f * 4,
    )
}

/// Fig 5 FFT CPU baseline: n-point Q15 radix-2 DIT, in-place over
/// `re_buf`/`im_buf`; `rev_tbl`, `wr_tbl`, `wi_tbl` injected by the CS.
pub fn fft_cpu(n: usize) -> String {
    assert!(n.is_power_of_two() && n >= 2);
    format!(
        r#"{PRELUDE}
.equ N, {n}
.equ NHALF, {nhalf}
_start:
    li  t0, GPIO
    li  t1, PERF_BIT
    sw  t1, 0(t0)
    la  s0, re_buf
    la  s1, im_buf
    la  s2, rev_tbl
    # ---- bit-reversal permutation ----
    li  t0, 0
bitrev_loop:
    slli t1, t0, 2
    add  t2, s2, t1
    lw   t3, 0(t2)       # j = rev[i]
    ble  t3, t0, brskip
    slli t4, t3, 2
    add  t5, s0, t1
    add  t6, s0, t4
    lw   a0, 0(t5)
    lw   a1, 0(t6)
    sw   a1, 0(t5)
    sw   a0, 0(t6)
    add  t5, s1, t1
    add  t6, s1, t4
    lw   a0, 0(t5)
    lw   a1, 0(t6)
    sw   a1, 0(t5)
    sw   a0, 0(t6)
brskip:
    addi t0, t0, 1
    li   t1, N
    bltu t0, t1, bitrev_loop
    # ---- stages ----
    la  s2, wr_tbl
    la  s3, wi_tbl
    li  s5, 2            # m
    li  s9, NHALF        # twiddle stride = N/m
stage_loop:
    srli s6, s5, 1       # half = m/2
    li   s7, 0           # grp
grp_loop:
    li   s8, 0           # j
j_loop:
    add  t0, s7, s8      # e
    add  t1, t0, s6      # o
    mul  t2, s8, s9      # tw
    slli t0, t0, 2
    slli t1, t1, 2
    slli t2, t2, 2
    add  a0, s0, t0      # &re[e]
    add  a1, s1, t0      # &im[e]
    add  a2, s0, t1      # &re[o]
    add  a3, s1, t1      # &im[o]
    add  a4, s2, t2      # &wr[tw]
    add  a5, s3, t2      # &wi[tw]
    lw   t3, 0(a2)       # or
    lw   t4, 0(a3)       # oi
    lw   t5, 0(a4)       # twr
    lw   t6, 0(a5)       # twi
    # q15(or*twr)
    mul  a6, t3, t5
    mulh a7, t3, t5
    srli a6, a6, 15
    slli a7, a7, 17
    or   a6, a6, a7
    # q15(oi*twi)
    mul  s10, t4, t6
    mulh s11, t4, t6
    srli s10, s10, 15
    slli s11, s11, 17
    or   s10, s10, s11
    sub  a6, a6, s10     # tr
    # q15(or*twi)
    mul  s10, t3, t6
    mulh s11, t3, t6
    srli s10, s10, 15
    slli s11, s11, 17
    or   s10, s10, s11
    # q15(oi*twr)
    mul  t3, t4, t5
    mulh t4, t4, t5
    srli t3, t3, 15
    slli t4, t4, 17
    or   t3, t3, t4
    add  s10, s10, t3    # ti
    lw   t5, 0(a0)       # er
    lw   t6, 0(a1)       # ei
    add  t3, t5, a6
    srai t3, t3, 1
    sw   t3, 0(a0)
    add  t4, t6, s10
    srai t4, t4, 1
    sw   t4, 0(a1)
    sub  t3, t5, a6
    srai t3, t3, 1
    sw   t3, 0(a2)
    sub  t4, t6, s10
    srai t4, t4, 1
    sw   t4, 0(a3)
    addi s8, s8, 1
    bltu s8, s6, j_loop
    add  s7, s7, s5
    li   t0, N
    bltu s7, t0, grp_loop
    slli s5, s5, 1
    srli s9, s9, 1
    li   t0, N
    ble  s5, t0, stage_loop
    li  t0, GPIO
    sw  zero, 0(t0)
    ebreak
.data
re_buf:  .space {nb}
im_buf:  .space {nb}
rev_tbl: .space {nb}
wr_tbl:  .space {hb}
wi_tbl:  .space {hb}
"#,
        nhalf = n / 2,
        nb = n * 4,
        hb = (n / 2) * 4,
    )
}

/// Shared CGRA-launch tail: wait for DONE (WFI on the CGRA irq line).
const CGRA_WAIT: &str = r#"
cgra_wait:
    lw   t2, 0(t0)       # STATUS
    andi t3, t2, 1
    bnez t3, cgra_done
    wfi
    j    cgra_wait
cgra_done:
    li  t1, GPIO
    sw  zero, 0(t1)      # close perf window
    ebreak
"#;

/// Fig 5 MM on the CGRA: program the control port and launch.
pub fn mm_cgra(m: usize, k: usize, n: usize) -> String {
    format!(
        r#"{PRELUDE}
_start:
    li  t0, GPIO
    li  t1, PERF_BIT
    sw  t1, 0(t0)
    li  t0, CGRA
    li  t1, 1
    sw  t1, 0x14(t0)     # CTRL: irq enable
    li  t1, MIE_CGRA
    csrw mie, t1
    sw  zero, 8(t0)      # KERNEL = MATMUL
    la  t1, a_buf
    sw  t1, 0x40(t0)
    la  t1, b_buf
    sw  t1, 0x44(t0)
    la  t1, c_buf
    sw  t1, 0x48(t0)
    li  t1, {m}
    sw  t1, 0x4C(t0)
    li  t1, {k}
    sw  t1, 0x50(t0)
    li  t1, {n}
    sw  t1, 0x54(t0)
    li  t1, 1
    sw  t1, 4(t0)        # START
{CGRA_WAIT}
.data
a_buf: .space {a_bytes}
b_buf: .space {b_bytes}
c_buf: .space {c_bytes}
"#,
        a_bytes = m * k * 4,
        b_bytes = k * n * 4,
        c_bytes = m * n * 4,
    )
}

/// Fig 5 CONV on the CGRA.
pub fn conv_cgra(h: usize, w: usize, cin: usize, f: usize, kh: usize, kw: usize) -> String {
    let oh = h - kh + 1;
    let ow = w - kw + 1;
    format!(
        r#"{PRELUDE}
_start:
    li  t0, GPIO
    li  t1, PERF_BIT
    sw  t1, 0(t0)
    li  t0, CGRA
    li  t1, 1
    sw  t1, 0x14(t0)
    li  t1, MIE_CGRA
    csrw mie, t1
    li  t1, 1
    sw  t1, 8(t0)        # KERNEL = CONV2D
    la  t1, x_buf
    sw  t1, 0x40(t0)
    la  t1, w_buf
    sw  t1, 0x44(t0)
    la  t1, y_buf
    sw  t1, 0x48(t0)
    li  t1, {h}
    sw  t1, 0x4C(t0)
    li  t1, {w}
    sw  t1, 0x50(t0)
    li  t1, {cin}
    sw  t1, 0x54(t0)
    li  t1, {f}
    sw  t1, 0x58(t0)
    li  t1, {kh}
    sw  t1, 0x5C(t0)
    li  t1, {kw}
    sw  t1, 0x60(t0)
    li  t1, 1
    sw  t1, 4(t0)
{CGRA_WAIT}
.data
x_buf: .space {x_bytes}
w_buf: .space {w_bytes}
y_buf: .space {y_bytes}
"#,
        x_bytes = h * w * cin * 4,
        w_bytes = f * kh * kw * cin * 4,
        y_bytes = oh * ow * f * 4,
    )
}

/// Fig 5 FFT on the CGRA: the guest performs the bit-reversal permutation
/// on the CPU (cheap, irregular), then launches the stage kernels.
pub fn fft_cgra(n: usize) -> String {
    assert!(n.is_power_of_two() && n >= 2);
    format!(
        r#"{PRELUDE}
.equ N, {n}
_start:
    li  t0, GPIO
    li  t1, PERF_BIT
    sw  t1, 0(t0)
    la  s0, re_buf
    la  s1, im_buf
    la  s2, rev_tbl
    li  t0, 0
bitrev_loop:
    slli t1, t0, 2
    add  t2, s2, t1
    lw   t3, 0(t2)
    ble  t3, t0, brskip
    slli t4, t3, 2
    add  t5, s0, t1
    add  t6, s0, t4
    lw   a0, 0(t5)
    lw   a1, 0(t6)
    sw   a1, 0(t5)
    sw   a0, 0(t6)
    add  t5, s1, t1
    add  t6, s1, t4
    lw   a0, 0(t5)
    lw   a1, 0(t6)
    sw   a1, 0(t5)
    sw   a0, 0(t6)
brskip:
    addi t0, t0, 1
    li   t1, N
    bltu t0, t1, bitrev_loop
    li  t0, CGRA
    li  t1, 1
    sw  t1, 0x14(t0)
    li  t1, MIE_CGRA
    csrw mie, t1
    li  t1, 2
    sw  t1, 8(t0)        # KERNEL = FFT
    la  t1, re_buf
    sw  t1, 0x40(t0)
    la  t1, im_buf
    sw  t1, 0x44(t0)
    la  t1, wr_tbl
    sw  t1, 0x48(t0)
    la  t1, wi_tbl
    sw  t1, 0x4C(t0)
    li  t1, N
    sw  t1, 0x50(t0)
    li  t1, 1
    sw  t1, 4(t0)
{CGRA_WAIT}
.data
re_buf:  .space {nb}
im_buf:  .space {nb}
rev_tbl: .space {nb}
wr_tbl:  .space {hb}
wi_tbl:  .space {hb}
"#,
        nb = n * 4,
        hb = (n / 2) * 4,
    )
}

/// §V-C-style end-to-end app: acquire `n` samples from the virtualized
/// ADC, copy the window into the mailbox request block in CS DRAM, ring
/// the doorbell for the `model` artifact (the PJRT-executed classifier),
/// wait for completion, read the logits back, argmax, and print the class
/// over UART.
///
/// Mailbox request layout at `BRIDGE + req_off` (word offsets):
/// `[kernel_id=3(model), n_args=1, window[n], logits[n_classes]]` — the
/// CS service knows the model shapes from the artifact manifest.
pub fn classifier_mailbox(n: usize, n_classes: usize, req_off: u32) -> String {
    format!(
        r#"{PRELUDE}
.equ NSAMPLES, {n}
.equ NCLASSES, {n_classes}
.equ REQ, {req}          # BRIDGE + req_off
_start:
    # ---- acquisition phase ----
    li  s0, SPI_ADC
    li  s1, NSAMPLES
    la  s2, window
    li  s3, 0
    li  t0, 3
    sw  t0, 0(s0)
    li  t0, MIE_ADC
    csrw mie, t0
acq:
    lw  t1, 4(s0)
    andi t2, t1, 1
    bnez t2, take
    wfi
    j   acq
take:
    lw  t3, 8(s0)
    sw  t3, 0(s2)
    addi s2, s2, 4
    addi s3, s3, 1
    bltu s3, s1, acq
    # ---- hand off to the virtualized accelerator ----
    li  s4, REQ
    li  t0, 3            # kernel id: model
    sw  t0, 0(s4)
    li  t0, 1            # one tensor argument (the window)
    sw  t0, 4(s4)
    la  s2, window
    addi s5, s4, 8       # request payload cursor
    li  s3, 0
copy:
    lw  t0, 0(s2)
    sw  t0, 0(s5)
    addi s2, s2, 4
    addi s5, s5, 4
    addi s3, s3, 1
    bltu s3, s1, copy
    li  t0, MBOX
    li  t1, 1
    sw  t1, 8(t0)        # CTRL: irq enable
    li  t1, MIE_MBOX
    csrw mie, t1
    li  t1, {req_off}
    sw  t1, 12(t0)       # REQ_OFF
    li  t1, 1
    sw  t1, 0(t0)        # DOORBELL
mwait:
    lw  t2, 4(t0)        # STATUS
    andi t3, t2, 1
    bnez t3, mdone
    wfi
    j   mwait
mdone:
    # ---- read logits (follow the window in the request block), argmax
    mv   t0, s5          # logits base = after window
    li   t1, 0           # best idx
    li   t2, 0           # i
    lw   t3, 0(t0)       # best val
argmax:
    addi t2, t2, 1
    li   t4, NCLASSES
    bgeu t2, t4, report
    slli t4, t2, 2
    add  t4, t0, t4
    lw   t5, 0(t4)
    ble  t5, t3, argmax
    mv   t3, t5
    mv   t1, t2
    j    argmax
report:
    li   t0, UART
    addi t1, t1, 67      # 'C' + class index
    sw   t1, 0(t0)
    li   t2, 10          # newline
    sw   t2, 0(t0)
    ebreak
.data
window: .space {win_bytes}
"#,
        req = 0x4000_0000u32 + req_off,
        win_bytes = n * 4,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::assemble;

    #[test]
    fn all_programs_assemble() {
        for (name, src) in [
            ("acq", acquisition(100, 2)),
            ("mm", mm_cpu(121, 16, 4)),
            ("conv", conv_cpu(16, 16, 3, 8, 3, 3)),
            ("fft", fft_cpu(512)),
            ("mm_cgra", mm_cgra(121, 16, 4)),
            ("conv_cgra", conv_cgra(16, 16, 3, 8, 3, 3)),
            ("fft_cgra", fft_cgra(512)),
            ("classifier", classifier_mailbox(512, 4, 0x1000)),
        ] {
            let prog = assemble(&src).unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert!(!prog.text.is_empty(), "{name}");
        }
    }

    #[test]
    fn buffers_have_expected_sizes() {
        let p = assemble(&mm_cpu(121, 16, 4)).unwrap();
        let a = p.symbol("a_buf").unwrap();
        let b = p.symbol("b_buf").unwrap();
        let c = p.symbol("c_buf").unwrap();
        assert_eq!(b - a, 121 * 16 * 4);
        assert_eq!(c - b, 16 * 4 * 4);
    }
}
