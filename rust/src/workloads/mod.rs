//! Guest workloads: reference implementations, signal/dataset generators,
//! and the RV32 assembly programs the case studies run on the emulated
//! X-HEEP host.

pub mod programs;
pub mod reference;
pub mod signals;

pub use reference::{bit_reverse_permute, fft_q15, twiddles_q15};

/// Names of the built-in guest programs, at their canonical parameters —
/// the set `femu analyze --builtin all` lints and CI keeps at zero
/// diagnostics.
pub const BUILTIN_NAMES: &[&str] = &[
    "acquisition",
    "classifier_mailbox",
    "conv_cgra",
    "conv_cpu",
    "fft_cgra",
    "fft_cpu",
    "mm_cgra",
    "mm_cpu",
];

/// The output buffers of the built-in workload `name` at its canonical
/// parameters, as `(symbol, length_in_bytes)` pairs — the memory a
/// correctness oracle (the fault-injection campaign's golden-record
/// diff, [`crate::faults`]) should digest to detect silent data
/// corruption. Empty for workloads whose observable output is UART-only.
/// `None` for an unknown name.
pub fn output_region(name: &str) -> Option<Vec<(&'static str, usize)>> {
    Some(match name {
        "acquisition" => vec![("buf", 4096)],
        "classifier_mailbox" => vec![], // UART-only observable output
        "conv_cgra" | "conv_cpu" => vec![("y_buf", 14 * 14 * 8 * 4)],
        "fft_cgra" | "fft_cpu" => vec![("re_buf", 512 * 4), ("im_buf", 512 * 4)],
        "mm_cgra" | "mm_cpu" => vec![("c_buf", 121 * 4 * 4)],
        _ => return None,
    })
}

/// Source of the built-in workload `name` at its canonical parameters
/// (the sizes the paper's case studies run), or `None` for an unknown
/// name.
pub fn builtin(name: &str) -> Option<String> {
    Some(match name {
        "acquisition" => programs::acquisition(100, 2),
        "classifier_mailbox" => programs::classifier_mailbox(512, 4, 0x1000),
        "conv_cgra" => programs::conv_cgra(16, 16, 3, 8, 3, 3),
        "conv_cpu" => programs::conv_cpu(16, 16, 3, 8, 3, 3),
        "fft_cgra" => programs::fft_cgra(512),
        "fft_cpu" => programs::fft_cpu(512),
        "mm_cgra" => programs::mm_cgra(121, 16, 4),
        "mm_cpu" => programs::mm_cpu(121, 16, 4),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_name_and_assembles() {
        for &name in BUILTIN_NAMES {
            let src = builtin(name).unwrap_or_else(|| panic!("{name} missing"));
            crate::isa::assemble(&src).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        }
        assert!(builtin("nope").is_none());
    }

    #[test]
    fn output_regions_name_real_symbols() {
        for &name in BUILTIN_NAMES {
            let regions = output_region(name).unwrap_or_else(|| panic!("{name} missing"));
            let prog = crate::isa::assemble(&builtin(name).unwrap()).unwrap();
            for (sym, len) in regions {
                let addr = prog
                    .symbol(sym)
                    .unwrap_or_else(|e| panic!("{name}: {sym}: {e:#}"));
                assert!(len > 0 && len % 4 == 0, "{name}: {sym} length {len}");
                // the region sits inside the program's data segment
                assert!(addr >= prog.data_base, "{name}: {sym} at {addr:#x}");
                assert!(
                    addr + len as u32 <= prog.data_base + prog.data.len() as u32,
                    "{name}: {sym} spills past the data segment"
                );
            }
        }
        assert!(output_region("nope").is_none());
    }
}
