//! Guest workloads: reference implementations, signal/dataset generators,
//! and the RV32 assembly programs the case studies run on the emulated
//! X-HEEP host.

pub mod programs;
pub mod reference;
pub mod signals;

pub use reference::{bit_reverse_permute, fft_q15, twiddles_q15};
