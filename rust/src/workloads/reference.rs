//! Rust mirror of the Python oracle (`python/compile/kernels/ref.py`).
//!
//! Every implementation in the stack — RV32 assembly on the emulated CPU,
//! CGRA mappings, and the AOT Pallas artifacts — must agree bit-for-bit
//! with these functions. The cross-checks live in `rust/tests/` and in
//! the Python test suite; the shared numeric contracts are:
//!
//! * INT32 two's-complement wrap-around for MM/CONV,
//! * Q15 multiplies as `(a as i64 * b as i64) >> 15`,
//! * FFT per-stage `>> 1` scaling,
//! * twiddle rounding `floor(x * 2^15 + 0.5)` clamped to `[-2^15, 2^15-1]`.

/// Q15 fractional bits.
pub const Q: u32 = 15;

/// INT32 matmul: (m x k) @ (k x n), row-major, wrap-around.
pub fn matmul_i32(a: &[i32], b: &[i32], m: usize, k: usize, n: usize) -> Vec<i32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0i32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i32;
            for kk in 0..k {
                acc = acc.wrapping_add(a[i * k + kk].wrapping_mul(b[kk * n + j]));
            }
            c[i * n + j] = acc;
        }
    }
    c
}

/// INT32 valid conv2d: x (h x w x cin, HWC), weights (f x kh x kw x cin),
/// output ((h-kh+1) x (w-kw+1) x f, HWC).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_i32(
    x: &[i32],
    wts: &[i32],
    h: usize,
    w: usize,
    cin: usize,
    f: usize,
    kh: usize,
    kw: usize,
) -> Vec<i32> {
    assert_eq!(x.len(), h * w * cin);
    assert_eq!(wts.len(), f * kh * kw * cin);
    let oh = h - kh + 1;
    let ow = w - kw + 1;
    let mut y = vec![0i32; oh * ow * f];
    for oy in 0..oh {
        for ox in 0..ow {
            for fi in 0..f {
                let mut acc = 0i32;
                for di in 0..kh {
                    for dj in 0..kw {
                        for ci in 0..cin {
                            let xv = x[((oy + di) * w + (ox + dj)) * cin + ci];
                            let wv = wts[((fi * kh + di) * kw + dj) * cin + ci];
                            acc = acc.wrapping_add(xv.wrapping_mul(wv));
                        }
                    }
                }
                y[(oy * ow + ox) * f + fi] = acc;
            }
        }
    }
    y
}

/// Q15 multiply with 64-bit intermediate (matches RV32 mul/mulh pair and
/// the CGRA MulQ15 functional unit).
#[inline]
pub fn q15_mul(a: i32, b: i32) -> i32 {
    ((a as i64 * b as i64) >> Q) as i32
}

/// Q15 twiddle tables for an n-point FFT: `(wr, wi)`, k in [0, n/2).
/// Rounding rule identical to `ref.twiddles_q15` in Python.
pub fn twiddles_q15(n: usize) -> (Vec<i32>, Vec<i32>) {
    let half = (n / 2).max(1);
    let scale = (1i64 << Q) as f64;
    let mut wr = Vec::with_capacity(half);
    let mut wi = Vec::with_capacity(half);
    for k in 0..half {
        let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
        let re = (ang.cos() * scale + 0.5).floor() as i64;
        let im = (ang.sin() * scale + 0.5).floor() as i64;
        wr.push(re.clamp(-(1 << Q), (1 << Q) - 1) as i32);
        wi.push(im.clamp(-(1 << Q), (1 << Q) - 1) as i32);
    }
    (wr, wi)
}

/// Per-stage twiddle tables in AOT-artifact order: stage s (1-based)
/// uses W^(j * n/2^s) for j < 2^(s-1); the artifact expects all the twr
/// tables, then all the twi tables (see python/compile/kernels/fft.py —
/// the tables are artifact *parameters* because dense constants do not
/// survive the HLO-text interchange).
pub fn fft_stage_twiddles(n: usize) -> Vec<Vec<i32>> {
    assert!(n.is_power_of_two() && n >= 2);
    let (wr, wi) = twiddles_q15(n);
    let stages = n.trailing_zeros() as usize;
    let mut twr = Vec::with_capacity(stages);
    let mut twi = Vec::with_capacity(stages);
    for s in 1..=stages {
        let half = 1usize << (s - 1);
        let stride = n >> s;
        twr.push((0..half).map(|j| wr[j * stride]).collect());
        twi.push((0..half).map(|j| wi[j * stride]).collect());
    }
    twr.extend(twi);
    twr
}

/// Bit-reversal permutation indices for n (power of two).
pub fn bit_reverse_indices(n: usize) -> Vec<usize> {
    assert!(n.is_power_of_two());
    let bits = n.trailing_zeros();
    (0..n)
        .map(|i| {
            let mut r = 0usize;
            for b in 0..bits {
                r |= ((i >> b) & 1) << (bits - 1 - b);
            }
            r
        })
        .collect()
}

/// Apply the bit-reversal permutation in place (the guest driver does
/// this before launching the CGRA FFT stages).
pub fn bit_reverse_permute(re: &mut [i32], im: &mut [i32]) {
    let n = re.len();
    let rev = bit_reverse_indices(n);
    for i in 0..n {
        let j = rev[i];
        if j > i {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
}

/// Radix-2 DIT Q15 FFT with per-stage >>1 scaling. In-place over
/// (re, im); input in natural order (the permutation is applied here).
pub fn fft_q15(re: &mut [i32], im: &mut [i32]) {
    let n = re.len();
    assert!(n.is_power_of_two() && n >= 2, "n must be a power of two >= 2");
    assert_eq!(im.len(), n);
    bit_reverse_permute(re, im);
    fft_q15_stages(re, im);
}

/// The stage loop only (expects bit-reversed input) — the exact work the
/// CGRA stage kernels perform.
pub fn fft_q15_stages(re: &mut [i32], im: &mut [i32]) {
    let n = re.len();
    let (wr, wi) = twiddles_q15(n);
    let stages = n.trailing_zeros();
    for s in 1..=stages {
        let m = 1usize << s;
        let half = m / 2;
        let stride = n / m;
        for grp in (0..n).step_by(m) {
            for j in 0..half {
                let e = grp + j;
                let o = e + half;
                let tw = j * stride;
                let (er, ei) = (re[e], im[e]);
                let (orr, oi) = (re[o], im[o]);
                let tr = q15_mul(orr, wr[tw]).wrapping_sub(q15_mul(oi, wi[tw]));
                let ti = q15_mul(orr, wi[tw]).wrapping_add(q15_mul(oi, wr[tw]));
                re[e] = er.wrapping_add(tr) >> 1;
                im[e] = ei.wrapping_add(ti) >> 1;
                re[o] = er.wrapping_sub(tr) >> 1;
                im[o] = ei.wrapping_sub(ti) >> 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a: Vec<i32> = (0..6).collect();
        let eye = vec![1, 0, 0, 1];
        assert_eq!(matmul_i32(&a, &eye, 3, 2, 2), a);
    }

    #[test]
    fn matmul_wraps() {
        let a = vec![i32::MAX, i32::MAX];
        let b = vec![2, 2];
        let c = matmul_i32(&a, &b, 1, 2, 1);
        assert_eq!(c[0], (i32::MAX.wrapping_mul(2)).wrapping_mul(2));
    }

    #[test]
    fn conv_delta_filter() {
        // 4x4x1 input, single 3x3 delta filter picks the center.
        let x: Vec<i32> = (0..16).collect();
        let mut w = vec![0i32; 9];
        w[4] = 1; // center tap
        let y = conv2d_i32(&x, &w, 4, 4, 1, 1, 3, 3);
        assert_eq!(y, vec![5, 6, 9, 10]);
    }

    #[test]
    fn twiddles_match_python_rule() {
        let (wr, wi) = twiddles_q15(8);
        // k=0: (0x7FFF clamped, 0); k=2: (0, -32768)
        assert_eq!(wr[0], 0x7FFF);
        assert_eq!(wi[0], 0);
        assert_eq!(wr[2], 0);
        assert_eq!(wi[2], -32768);
        // k=1: cos(-45deg)=0.7071 -> floor(23170.47+0.5)=23170
        assert_eq!(wr[1], 23170);
        assert_eq!(wi[1], -23170);
    }

    #[test]
    fn bitrev_indices_n8() {
        assert_eq!(bit_reverse_indices(8), vec![0, 4, 2, 6, 1, 5, 3, 7]);
    }

    #[test]
    fn fft_impulse_flat_spectrum() {
        let n = 64;
        let mut re = vec![0i32; n];
        let mut im = vec![0i32; n];
        re[0] = 1 << 15;
        fft_q15(&mut re, &mut im);
        let expected = (1 << 15) >> 6;
        assert!(re.iter().all(|&x| x == expected), "{re:?}");
        assert!(im.iter().all(|&x| x == 0));
    }

    #[test]
    fn fft_dc_with_q15_attrition() {
        let n = 32;
        let mut re = vec![1000i32; n];
        let mut im = vec![0i32; n];
        fft_q15(&mut re, &mut im);
        assert!((990..=1000).contains(&re[0]), "{}", re[0]);
        assert!(re[1..].iter().all(|&x| x.abs() <= 2));
    }

    #[test]
    fn q15_mul_matches_shift_semantics() {
        assert_eq!(q15_mul(-30000, 0x4000), -15000);
        assert_eq!(q15_mul(i32::MIN, 0x7FFF), ((i32::MIN as i64 * 0x7FFF) >> 15) as i32);
        // floor behavior for negative products
        assert_eq!(q15_mul(-1, 1), -1); // -1*1 >> 15 = -1 (floor)
    }
}
