//! Instruction-class cycle costs (CV32E40P-like defaults).
//!
//! The emulator is cycle-*approximate*: per-class base costs plus bus wait
//! states. Defaults follow the CV32E40P datasheet shape (single-cycle ALU
//! and MUL, multi-cycle DIV, taken-branch flush penalty); all values are
//! configurable from the platform TOML ([`crate::config`]) so a different
//! host core can be modeled without recompiling.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Timing {
    /// ALU / LUI / AUIPC / FENCE base cost.
    pub alu: u32,
    /// MUL/MULH* cost (CV32E40P: 1 for MUL, 5 for MULH; we use the MUL
    /// figure — MULH appears only in Q15 sequences where the pair is the
    /// unit of work).
    pub mul: u32,
    /// DIV/REM cost (CV32E40P: 3..35; fixed worst-ish case).
    pub div: u32,
    /// Load base cost (plus bus wait states).
    pub load: u32,
    /// Store base cost (plus bus wait states).
    pub store: u32,
    /// Branch base cost.
    pub branch: u32,
    /// Extra cycles when a branch is taken (pipeline flush).
    pub branch_taken_penalty: u32,
    /// JAL/JALR/MRET cost.
    pub jump: u32,
    /// CSR access cost.
    pub csr: u32,
    /// Trap entry (interrupt or exception) cost.
    pub trap_entry: u32,
    /// WFI wake-up cost (clock ungating).
    pub wake: u32,
}

impl Default for Timing {
    fn default() -> Self {
        Self {
            alu: 1,
            mul: 1,
            div: 34,
            load: 2,
            store: 1,
            branch: 1,
            branch_taken_penalty: 2,
            jump: 2,
            csr: 1,
            trap_entry: 4,
            wake: 6,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let t = Timing::default();
        assert!(t.div > t.mul);
        assert!(t.load >= 1 && t.trap_entry >= 1 && t.wake >= 1);
    }
}
