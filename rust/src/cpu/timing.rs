//! Instruction-class cycle costs (CV32E40P-like defaults).
//!
//! The emulator is cycle-*approximate*: per-class base costs plus bus wait
//! states. Defaults follow the CV32E40P datasheet shape (single-cycle ALU
//! and MUL, multi-cycle DIV, taken-branch flush penalty); all values are
//! configurable from the platform TOML ([`crate::config`]) so a different
//! host core can be modeled without recompiling.

use crate::isa::{AluOp, Instr};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Timing {
    /// ALU / LUI / AUIPC / FENCE base cost.
    pub alu: u32,
    /// MUL/MULH* cost (CV32E40P: 1 for MUL, 5 for MULH; we use the MUL
    /// figure — MULH appears only in Q15 sequences where the pair is the
    /// unit of work).
    pub mul: u32,
    /// DIV/REM cost (CV32E40P: 3..35; fixed worst-ish case).
    pub div: u32,
    /// Load base cost (plus bus wait states).
    pub load: u32,
    /// Store base cost (plus bus wait states).
    pub store: u32,
    /// Branch base cost.
    pub branch: u32,
    /// Extra cycles when a branch is taken (pipeline flush).
    pub branch_taken_penalty: u32,
    /// JAL/JALR/MRET cost.
    pub jump: u32,
    /// CSR access cost.
    pub csr: u32,
    /// Trap entry (interrupt or exception) cost.
    pub trap_entry: u32,
    /// WFI wake-up cost (clock ungating).
    pub wake: u32,
}

impl Default for Timing {
    fn default() -> Self {
        Self {
            alu: 1,
            mul: 1,
            div: 34,
            load: 2,
            store: 1,
            branch: 1,
            branch_taken_penalty: 2,
            jump: 2,
            csr: 1,
            trap_entry: 4,
            wake: 6,
        }
    }
}

impl Timing {
    /// Worst-case cycle cost of one instruction executed from SRAM
    /// (zero wait states): the base class cost, or the trap-entry cost
    /// where the instruction can fault. This is the single bound shared
    /// by the block backend's dispatch budget ([`crate::exec::blocks`])
    /// and the static analyzer's WCET accounting
    /// ([`crate::analyze`]) — one table, two consumers, no drift.
    ///
    /// Accesses that leave SRAM cost extra bus wait states on top; the
    /// analyzer adds those separately where it can prove the target
    /// window, and the block backend never replays them.
    pub fn worst_cycles(&self, instr: Instr) -> u32 {
        match instr {
            Instr::Lui { .. } | Instr::Auipc { .. } | Instr::OpImm { .. } | Instr::Fence => {
                self.alu
            }
            Instr::Jal { .. } | Instr::Jalr { .. } | Instr::Mret => self.jump,
            Instr::Branch { .. } => self.branch + self.branch_taken_penalty,
            Instr::Load { .. } => self.load.max(self.trap_entry),
            Instr::Store { .. } => self.store.max(self.trap_entry),
            Instr::Op { op, .. } => match op {
                AluOp::Mul | AluOp::Mulh | AluOp::Mulhsu | AluOp::Mulhu => self.mul,
                AluOp::Div | AluOp::Divu | AluOp::Rem | AluOp::Remu => self.div,
                _ => self.alu,
            },
            Instr::Ecall => self.trap_entry,
            Instr::Ebreak | Instr::Wfi => self.alu,
            Instr::Csr { .. } => self.csr.max(self.trap_entry),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let t = Timing::default();
        assert!(t.div > t.mul);
        assert!(t.load >= 1 && t.trap_entry >= 1 && t.wake >= 1);
    }

    #[test]
    fn worst_cycles_covers_every_class() {
        let t = Timing::default();
        assert_eq!(t.worst_cycles(Instr::Lui { rd: 1, imm: 0 }), t.alu);
        assert_eq!(t.worst_cycles(Instr::Ecall), t.trap_entry);
        assert_eq!(
            t.worst_cycles(Instr::Branch {
                op: crate::isa::BranchOp::Eq,
                rs1: 0,
                rs2: 0,
                imm: 8
            }),
            t.branch + t.branch_taken_penalty
        );
        assert_eq!(
            t.worst_cycles(Instr::Op { op: AluOp::Div, rd: 1, rs1: 2, rs2: 3 }),
            t.div
        );
    }
}
