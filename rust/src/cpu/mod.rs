//! RV32IM machine-mode CPU core with an instruction-level timing model.
//!
//! Models the X-HEEP host core (a CV32E40-class in-order RISC-V): one
//! instruction per step with per-class cycle costs, machine-mode CSRs,
//! machine-timer + fast external interrupts, and WFI clock-gating (the
//! hook the acquisition workloads use to sleep between samples, which is
//! what Fig 4's active/sleep split measures).
//!
//! The core is bus-agnostic: [`BusAccess`] is implemented by
//! [`crate::bus::Bus`]; tests use flat test buses.

mod csrs;
mod timing;

pub use csrs::Csrs;
pub use timing::Timing;

use crate::isa::{self, AluOp, BranchOp, CsrOp, Instr, LoadOp, StoreOp};

/// Memory access width.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Size {
    Byte,
    Half,
    Word,
}

/// Bus fault kinds, mapped to RISC-V access-fault causes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BusFault {
    /// No device at this address, or device rejected the access.
    Access,
    /// Target memory bank is power-gated / in retention.
    NotPowered,
}

/// The CPU's window onto the interconnect. All methods return the value
/// (for reads) plus the number of **extra** wait-state cycles beyond the
/// base instruction cost.
pub trait BusAccess {
    fn fetch32(&mut self, addr: u32, now: u64) -> Result<(u32, u32), BusFault>;
    fn read(&mut self, addr: u32, size: Size, now: u64) -> Result<(u32, u32), BusFault>;
    fn write(&mut self, addr: u32, size: Size, value: u32, now: u64) -> Result<u32, BusFault>;
}

/// Why the core stopped executing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Halt {
    /// `ebreak` — the program-finished / debugger-breakpoint convention.
    Ebreak,
    /// Trap taken with `mtvec == 0` (no handler installed): a guest bug;
    /// halting beats spinning through the zero page.
    UnhandledTrap { cause: u32, pc: u32 },
}

/// Core execution state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CpuState {
    Running,
    /// In WFI: clock-gated until an enabled interrupt is pending.
    Sleeping,
    Halted(Halt),
}

/// Result of one `step`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StepResult {
    /// Cycles consumed by this step (base cost + wait states).
    pub cycles: u32,
    /// Instruction retired (false for WFI sleep poll / halted).
    pub retired: bool,
}

/// Machine-level interrupt cause bits in `mip`/`mie`.
pub mod int {
    /// Machine timer interrupt (standard bit 7).
    pub const MTIP: u32 = 1 << 7;
    /// Fast external lines (CV32E40P-style custom bits 16..): see
    /// [`crate::periph::irq`] for the line assignments.
    pub const FAST_BASE: u32 = 16;

    pub fn fast(line: u32) -> u32 {
        1 << (FAST_BASE + line)
    }
}

/// Trap causes.
pub mod cause {
    pub const ILLEGAL_INSTR: u32 = 2;
    pub const BREAKPOINT: u32 = 3;
    pub const LOAD_MISALIGNED: u32 = 4;
    pub const LOAD_FAULT: u32 = 5;
    pub const STORE_MISALIGNED: u32 = 6;
    pub const STORE_FAULT: u32 = 7;
    pub const ECALL_M: u32 = 11;
    pub const INT_FLAG: u32 = 0x8000_0000;

    pub fn interrupt(bit: u32) -> u32 {
        INT_FLAG | bit
    }
}

/// Decode-cache capacity in words (covers the low SRAM region where code
/// lives; 64K words = 256 KiB of text).
const ICACHE_WORDS: usize = 1 << 16;

/// Rolling digest of the retired-instruction stream, compared by the
/// lockstep diff driver ([`crate::exec::diff`]): two backends executed
/// the same program iff their digests match at every checkpoint. Keeps a
/// short ring of recent pcs so a divergence report can say *where*.
/// Never serialized into snapshots — enabling a trace must not change
/// snapshot payloads (they are byte-compared across backends).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RetireTrace {
    /// Instructions recorded.
    pub count: u64,
    /// FNV-1a over the little-endian pc stream.
    pub hash: u64,
    /// Ring of the most recent retired pcs (index `count % len`).
    pub recent: [u32; 8],
}

impl Default for RetireTrace {
    fn default() -> Self {
        Self { count: 0, hash: 0xcbf2_9ce4_8422_2325, recent: [0; 8] }
    }
}

impl RetireTrace {
    #[inline]
    fn note(&mut self, pc: u32) {
        self.recent[(self.count % self.recent.len() as u64) as usize] = pc;
        self.count += 1;
        for b in pc.to_le_bytes() {
            self.hash = (self.hash ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
    }

    /// Most recent retired pcs, oldest first (divergence diagnostics).
    pub fn recent_pcs(&self) -> Vec<u32> {
        let n = (self.count.min(self.recent.len() as u64)) as usize;
        (0..n)
            .map(|i| {
                let idx = (self.count - n as u64 + i as u64) % self.recent.len() as u64;
                self.recent[idx as usize]
            })
            .collect()
    }
}

#[derive(Clone, Debug)]
pub struct Cpu {
    pub regs: [u32; 32],
    pub pc: u32,
    pub csrs: Csrs,
    pub state: CpuState,
    pub timing: Timing,
    /// Retired instruction counter (also visible as minstret).
    pub instret: u64,
    /// Interrupts vectored into a handler, cumulative. Architectural in
    /// the sense that both backends take interrupts at identical cycles
    /// (DESIGN.md §11), so it snapshots byte-identically; trace
    /// validation cross-checks IRQ events against it.
    pub irqs_taken: u64,
    /// When present, every retired instruction's pc is folded into this
    /// digest (the diff driver's lockstep evidence). Off by default —
    /// the hot path pays one `Option` check. Not serialized; survives
    /// `reset` so a driver can arm it before loading a program.
    pub trace: Option<Box<RetireTrace>>,
    /// Pre-decoded instruction cache, tagged by the raw fetched word:
    /// `icache[pc >> 2] = (word, decoded)`. Tagging by the word itself
    /// makes the cache self-invalidating under self-modifying code and
    /// reprogramming (if memory changed, the tag mismatches and the slot
    /// is re-decoded) — the §Perf pass's first optimization
    /// (EXPERIMENTS.md §Perf, opt 1).
    icache: Vec<(u32, Instr)>,
}

impl Cpu {
    pub fn new(pc: u32) -> Self {
        Self {
            regs: [0; 32],
            pc,
            csrs: Csrs::new(),
            state: CpuState::Running,
            timing: Timing::default(),
            instret: 0,
            irqs_taken: 0,
            trace: None,
            // tag 0 never matches a real instruction word 0 because word
            // 0 does not decode; pre-fill with an unencodable pair
            icache: vec![(0, Instr::Fence); ICACHE_WORDS],
        }
    }

    pub fn reset(&mut self, pc: u32) {
        self.regs = [0; 32];
        self.pc = pc;
        self.csrs = Csrs::new();
        self.state = CpuState::Running;
        self.instret = 0;
        self.irqs_taken = 0;
    }

    #[inline]
    fn set_reg(&mut self, rd: u8, v: u32) {
        if rd != 0 {
            self.regs[rd as usize] = v;
        }
    }

    /// Update the external interrupt pending lines (level-sensitive: the
    /// SoC recomputes them after every step / event).
    pub fn set_irq_lines(&mut self, mtip: bool, fast_lines: u32) {
        let mut mip = self.csrs.mip & !(int::MTIP | (0xFFFF << int::FAST_BASE));
        if mtip {
            mip |= int::MTIP;
        }
        mip |= fast_lines << int::FAST_BASE;
        self.csrs.mip = mip;
    }

    /// True if an enabled interrupt is pending (wake condition for WFI).
    #[inline]
    pub fn interrupt_pending(&self) -> bool {
        self.csrs.mie & self.csrs.mip != 0
    }

    /// True when the next step would vector into an interrupt handler
    /// instead of executing an instruction (pending, enabled, and
    /// globally unmasked). The block backend refuses to dispatch a
    /// compiled block while this holds, so interrupt entry always goes
    /// through the single-step path.
    #[inline]
    pub fn irq_ready(&self) -> bool {
        self.csrs.mie_global() && self.interrupt_pending()
    }

    /// Take the highest-priority pending interrupt if globally enabled.
    /// Returns the trap entry cost if one was taken.
    fn maybe_take_interrupt(&mut self) -> Option<u32> {
        if !self.csrs.mie_global() {
            return None;
        }
        let pending = self.csrs.mie & self.csrs.mip;
        if pending == 0 {
            return None;
        }
        // priority: fast lines (high bit first), then timer
        let bit = 31 - pending.leading_zeros();
        self.trap(cause::interrupt(bit), 0);
        // only count interrupts that actually vectored (mtvec==0 halts)
        if !matches!(self.state, CpuState::Halted(_)) {
            self.irqs_taken += 1;
        }
        Some(self.timing.trap_entry)
    }

    /// Enter a trap: save pc/cause, jump to mtvec. With mtvec unset the
    /// core halts (see [`Halt::UnhandledTrap`]).
    fn trap(&mut self, cause_val: u32, tval: u32) {
        if self.csrs.mtvec == 0 {
            self.state = CpuState::Halted(Halt::UnhandledTrap { cause: cause_val, pc: self.pc });
            return;
        }
        self.csrs.mepc = self.pc;
        self.csrs.mcause = cause_val;
        self.csrs.mtval = tval;
        self.csrs.push_mie();
        // vectored mode (mtvec[0]=1): interrupts jump to base + 4*cause
        let base = self.csrs.mtvec & !3;
        if self.csrs.mtvec & 1 != 0 && cause_val & cause::INT_FLAG != 0 {
            self.pc = base + 4 * (cause_val & 0x7FFF_FFFF);
        } else {
            self.pc = base;
        }
    }

    /// Execute one instruction (or one sleep poll). `now` is the global
    /// cycle counter at the start of the step.
    pub fn step<B: BusAccess>(&mut self, bus: &mut B, now: u64) -> StepResult {
        match self.state {
            CpuState::Halted(_) => return StepResult { cycles: 0, retired: false },
            CpuState::Sleeping => {
                if self.interrupt_pending() {
                    self.state = CpuState::Running;
                    // wake: if globally enabled, vector immediately
                    let cost = self.maybe_take_interrupt().unwrap_or(self.timing.wake);
                    return StepResult { cycles: cost, retired: false };
                }
                // caller (SoC) fast-forwards to the next event; this cost
                // covers one idle poll if it chooses to tick instead
                return StepResult { cycles: 1, retired: false };
            }
            CpuState::Running => {}
        }

        if let Some(cost) = self.maybe_take_interrupt() {
            return StepResult { cycles: cost, retired: false };
        }

        // fetch
        let (word, fetch_wait) = match bus.fetch32(self.pc, now) {
            Ok(w) => w,
            Err(_) => {
                self.trap(cause::LOAD_FAULT, self.pc);
                return StepResult { cycles: self.timing.trap_entry, retired: false };
            }
        };
        // decode (through the word-tagged cache: a hit skips the decoder
        // entirely; word 0 never decodes, so the zero tag is safe)
        let slot = (self.pc >> 2) as usize;
        let instr = if slot < ICACHE_WORDS {
            let cached = self.icache[slot];
            if cached.0 == word {
                cached.1
            } else {
                let Some(instr) = isa::decode(word) else {
                    self.trap(cause::ILLEGAL_INSTR, word);
                    return StepResult { cycles: self.timing.trap_entry, retired: false };
                };
                self.icache[slot] = (word, instr);
                instr
            }
        } else {
            let Some(instr) = isa::decode(word) else {
                self.trap(cause::ILLEGAL_INSTR, word);
                return StepResult { cycles: self.timing.trap_entry, retired: false };
            };
            instr
        };

        self.exec_decoded(instr, word, fetch_wait, bus, now)
    }

    /// Execute one already-fetched, already-decoded instruction at the
    /// current pc. Split out of [`Cpu::step`] so every execution backend
    /// shares one set of semantics: the block backend replays pre-decoded
    /// blocks through this exact function (with `fetch_wait` 0 — block
    /// dispatch is restricted to SRAM, which fetches with zero wait
    /// states), so an instruction behaves bit-identically no matter which
    /// backend drives it.
    pub(crate) fn exec_decoded<B: BusAccess>(
        &mut self,
        instr: Instr,
        word: u32,
        fetch_wait: u32,
        bus: &mut B,
        now: u64,
    ) -> StepResult {
        let retired_pc = self.pc;
        let mut cycles = fetch_wait;
        let mut next_pc = self.pc.wrapping_add(4);

        macro_rules! trap_ret {
            ($cause:expr, $tval:expr) => {{
                self.trap($cause, $tval);
                return StepResult { cycles: cycles + self.timing.trap_entry, retired: false };
            }};
        }

        match instr {
            Instr::Lui { rd, imm } => {
                self.set_reg(rd, imm as u32);
                cycles += self.timing.alu;
            }
            Instr::Auipc { rd, imm } => {
                self.set_reg(rd, self.pc.wrapping_add(imm as u32));
                cycles += self.timing.alu;
            }
            Instr::Jal { rd, imm } => {
                self.set_reg(rd, next_pc);
                next_pc = self.pc.wrapping_add(imm as u32);
                cycles += self.timing.jump;
            }
            Instr::Jalr { rd, rs1, imm } => {
                let target = self.regs[rs1 as usize].wrapping_add(imm as u32) & !1;
                self.set_reg(rd, next_pc);
                next_pc = target;
                cycles += self.timing.jump;
            }
            Instr::Branch { op, rs1, rs2, imm } => {
                let a = self.regs[rs1 as usize];
                let b = self.regs[rs2 as usize];
                let taken = match op {
                    BranchOp::Eq => a == b,
                    BranchOp::Ne => a != b,
                    BranchOp::Lt => (a as i32) < (b as i32),
                    BranchOp::Ge => (a as i32) >= (b as i32),
                    BranchOp::Ltu => a < b,
                    BranchOp::Geu => a >= b,
                };
                cycles += self.timing.branch;
                if taken {
                    next_pc = self.pc.wrapping_add(imm as u32);
                    cycles += self.timing.branch_taken_penalty;
                }
            }
            Instr::Load { op, rd, rs1, imm } => {
                let addr = self.regs[rs1 as usize].wrapping_add(imm as u32);
                let (size, align) = match op {
                    LoadOp::Lb | LoadOp::Lbu => (Size::Byte, 1),
                    LoadOp::Lh | LoadOp::Lhu => (Size::Half, 2),
                    LoadOp::Lw => (Size::Word, 4),
                };
                if addr % align != 0 {
                    trap_ret!(cause::LOAD_MISALIGNED, addr);
                }
                let (raw, wait) = match bus.read(addr, size, now) {
                    Ok(r) => r,
                    Err(_) => trap_ret!(cause::LOAD_FAULT, addr),
                };
                let value = match op {
                    LoadOp::Lb => raw as u8 as i8 as i32 as u32,
                    LoadOp::Lbu => raw as u8 as u32,
                    LoadOp::Lh => raw as u16 as i16 as i32 as u32,
                    LoadOp::Lhu => raw as u16 as u32,
                    LoadOp::Lw => raw,
                };
                self.set_reg(rd, value);
                cycles += self.timing.load + wait;
            }
            Instr::Store { op, rs1, rs2, imm } => {
                let addr = self.regs[rs1 as usize].wrapping_add(imm as u32);
                let (size, align) = match op {
                    StoreOp::Sb => (Size::Byte, 1),
                    StoreOp::Sh => (Size::Half, 2),
                    StoreOp::Sw => (Size::Word, 4),
                };
                if addr % align != 0 {
                    trap_ret!(cause::STORE_MISALIGNED, addr);
                }
                let value = self.regs[rs2 as usize];
                let wait = match bus.write(addr, size, value, now) {
                    Ok(w) => w,
                    Err(_) => trap_ret!(cause::STORE_FAULT, addr),
                };
                cycles += self.timing.store + wait;
            }
            Instr::OpImm { op, rd, rs1, imm } => {
                let a = self.regs[rs1 as usize];
                let v = alu(op, a, imm as u32);
                self.set_reg(rd, v);
                cycles += self.timing.alu;
            }
            Instr::Op { op, rd, rs1, rs2 } => {
                let a = self.regs[rs1 as usize];
                let b = self.regs[rs2 as usize];
                let v = alu(op, a, b);
                self.set_reg(rd, v);
                cycles += match op {
                    AluOp::Mul | AluOp::Mulh | AluOp::Mulhsu | AluOp::Mulhu => self.timing.mul,
                    AluOp::Div | AluOp::Divu | AluOp::Rem | AluOp::Remu => self.timing.div,
                    _ => self.timing.alu,
                };
            }
            Instr::Fence => cycles += self.timing.alu,
            Instr::Ecall => trap_ret!(cause::ECALL_M, 0),
            Instr::Ebreak => {
                self.state = CpuState::Halted(Halt::Ebreak);
                self.note_retire(retired_pc);
                return StepResult { cycles: cycles + self.timing.alu, retired: true };
            }
            Instr::Wfi => {
                self.state = CpuState::Sleeping;
                self.pc = next_pc;
                self.instret += 1;
                self.note_retire(retired_pc);
                return StepResult { cycles: cycles + self.timing.alu, retired: true };
            }
            Instr::Mret => {
                self.csrs.pop_mie();
                next_pc = self.csrs.mepc;
                cycles += self.timing.jump;
            }
            Instr::Csr { op, rd, rs1, csr, imm } => {
                let old = match self.csrs.read(csr, now, self.instret) {
                    Some(v) => v,
                    None => trap_ret!(cause::ILLEGAL_INSTR, word),
                };
                let operand = if imm { rs1 as u32 } else { self.regs[rs1 as usize] };
                let new = match op {
                    CsrOp::Rw => Some(operand),
                    // rs1=x0 (or zimm 0) means "read only, do not write"
                    CsrOp::Rs => (rs1 != 0).then_some(old | operand),
                    CsrOp::Rc => (rs1 != 0).then_some(old & !operand),
                };
                if let Some(new) = new {
                    if !self.csrs.write(csr, new) {
                        trap_ret!(cause::ILLEGAL_INSTR, word);
                    }
                }
                self.set_reg(rd, old);
                cycles += self.timing.csr;
            }
        }

        self.pc = next_pc;
        self.instret += 1;
        self.note_retire(retired_pc);
        StepResult { cycles, retired: true }
    }

    #[inline]
    fn note_retire(&mut self, pc: u32) {
        if let Some(t) = &mut self.trace {
            t.note(pc);
        }
    }
}

impl Cpu {
    /// Serialize the architectural state (registers, pc, CSRs, execution
    /// state, timing model, instret). The decode cache is **not**
    /// captured: it is tagged by the raw instruction word, so any entry
    /// is valid against whatever memory image is restored around it.
    pub fn save_state(&self, w: &mut crate::snapshot::Writer) {
        for &r in &self.regs {
            w.u32(r);
        }
        w.u32(self.pc);
        self.csrs.save_state(w);
        match self.state {
            CpuState::Running => w.u8(0),
            CpuState::Sleeping => w.u8(1),
            CpuState::Halted(Halt::Ebreak) => w.u8(2),
            CpuState::Halted(Halt::UnhandledTrap { cause, pc }) => {
                w.u8(3);
                w.u32(cause);
                w.u32(pc);
            }
        }
        for t in [
            self.timing.alu,
            self.timing.mul,
            self.timing.div,
            self.timing.load,
            self.timing.store,
            self.timing.branch,
            self.timing.branch_taken_penalty,
            self.timing.jump,
            self.timing.csr,
            self.timing.trap_entry,
            self.timing.wake,
        ] {
            w.u32(t);
        }
        w.u64(self.instret);
        w.u64(self.irqs_taken); // snapshot v2
    }

    pub fn restore_state(&mut self, r: &mut crate::snapshot::Reader) -> anyhow::Result<()> {
        for reg in &mut self.regs {
            *reg = r.u32()?;
        }
        self.pc = r.u32()?;
        self.csrs.restore_state(r)?;
        self.state = match r.u8()? {
            0 => CpuState::Running,
            1 => CpuState::Sleeping,
            2 => CpuState::Halted(Halt::Ebreak),
            3 => {
                let cause = r.u32()?;
                let pc = r.u32()?;
                CpuState::Halted(Halt::UnhandledTrap { cause, pc })
            }
            other => anyhow::bail!("snapshot corrupt: cpu state tag {other}"),
        };
        self.timing.alu = r.u32()?;
        self.timing.mul = r.u32()?;
        self.timing.div = r.u32()?;
        self.timing.load = r.u32()?;
        self.timing.store = r.u32()?;
        self.timing.branch = r.u32()?;
        self.timing.branch_taken_penalty = r.u32()?;
        self.timing.jump = r.u32()?;
        self.timing.csr = r.u32()?;
        self.timing.trap_entry = r.u32()?;
        self.timing.wake = r.u32()?;
        self.instret = r.u64()?;
        self.irqs_taken = r.u64()?;
        Ok(())
    }
}

/// The one ALU evaluation function: the interpreter executes through it
/// and the static analyzer's constant propagation folds through it
/// ([`crate::analyze`]), so resolved addresses can never drift from what
/// execution computes.
#[inline]
pub(crate) fn alu(op: AluOp, a: u32, b: u32) -> u32 {
    match op {
        AluOp::Add => a.wrapping_add(b),
        AluOp::Sub => a.wrapping_sub(b),
        AluOp::Sll => a << (b & 31),
        AluOp::Slt => ((a as i32) < (b as i32)) as u32,
        AluOp::Sltu => (a < b) as u32,
        AluOp::Xor => a ^ b,
        AluOp::Srl => a >> (b & 31),
        AluOp::Sra => ((a as i32) >> (b & 31)) as u32,
        AluOp::Or => a | b,
        AluOp::And => a & b,
        AluOp::Mul => a.wrapping_mul(b),
        AluOp::Mulh => (((a as i32 as i64) * (b as i32 as i64)) >> 32) as u32,
        AluOp::Mulhsu => (((a as i32 as i64) * (b as u64 as i64)) >> 32) as u32,
        AluOp::Mulhu => (((a as u64) * (b as u64)) >> 32) as u32,
        AluOp::Div => {
            if b == 0 {
                u32::MAX
            } else if a == 0x8000_0000 && b == u32::MAX {
                a // overflow: -2^31 / -1
            } else {
                ((a as i32) / (b as i32)) as u32
            }
        }
        AluOp::Divu => {
            if b == 0 {
                u32::MAX
            } else {
                a / b
            }
        }
        AluOp::Rem => {
            if b == 0 {
                a
            } else if a == 0x8000_0000 && b == u32::MAX {
                0
            } else {
                ((a as i32) % (b as i32)) as u32
            }
        }
        AluOp::Remu => {
            if b == 0 {
                a
            } else {
                a % b
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::assemble;

    /// Flat 64 KiB RAM test bus, no wait states.
    struct FlatBus {
        mem: Vec<u8>,
    }

    impl FlatBus {
        fn new(prog: &crate::isa::Program) -> Self {
            let mut mem = vec![0u8; 0x40000];
            for (i, w) in prog.text.iter().enumerate() {
                mem[prog.text_base as usize + i * 4..][..4].copy_from_slice(&w.to_le_bytes());
            }
            let db = prog.data_base as usize;
            mem[db..db + prog.data.len()].copy_from_slice(&prog.data);
            Self { mem }
        }
    }

    impl BusAccess for FlatBus {
        fn fetch32(&mut self, addr: u32, _now: u64) -> Result<(u32, u32), BusFault> {
            let a = addr as usize;
            if a + 4 > self.mem.len() {
                return Err(BusFault::Access);
            }
            Ok((u32::from_le_bytes(self.mem[a..a + 4].try_into().unwrap()), 0))
        }

        fn read(&mut self, addr: u32, size: Size, now: u64) -> Result<(u32, u32), BusFault> {
            let a = addr as usize;
            let n = match size {
                Size::Byte => 1,
                Size::Half => 2,
                Size::Word => 4,
            };
            if a + n > self.mem.len() {
                return Err(BusFault::Access);
            }
            let mut bytes = [0u8; 4];
            bytes[..n].copy_from_slice(&self.mem[a..a + n]);
            let _ = now;
            Ok((u32::from_le_bytes(bytes), 0))
        }

        fn write(&mut self, addr: u32, size: Size, value: u32, _now: u64) -> Result<u32, BusFault> {
            let a = addr as usize;
            let n = match size {
                Size::Byte => 1,
                Size::Half => 2,
                Size::Word => 4,
            };
            if a + n > self.mem.len() {
                return Err(BusFault::Access);
            }
            self.mem[a..a + n].copy_from_slice(&value.to_le_bytes()[..n]);
            Ok(0)
        }
    }

    fn run(src: &str) -> (Cpu, FlatBus, u64) {
        let prog = assemble(src).expect("assemble");
        let mut bus = FlatBus::new(&prog);
        let mut cpu = Cpu::new(prog.entry);
        let mut now = 0u64;
        for _ in 0..1_000_000 {
            if matches!(cpu.state, CpuState::Halted(_)) {
                return (cpu, bus, now);
            }
            let r = cpu.step(&mut bus, now);
            now += r.cycles as u64;
        }
        panic!("program did not halt; pc={:#x}", cpu.pc);
    }

    #[test]
    fn arithmetic_program() {
        let (cpu, _, _) = run(
            r#"
            _start:
                li a0, 7
                li a1, 6
                mul a2, a0, a1      # 42
                li a3, -15
                div a4, a3, a0      # -2 (toward zero)
                rem a5, a3, a0      # -1
                ebreak
            "#,
        );
        assert_eq!(cpu.regs[12], 42);
        assert_eq!(cpu.regs[14] as i32, -2);
        assert_eq!(cpu.regs[15] as i32, -1);
        assert_eq!(cpu.state, CpuState::Halted(Halt::Ebreak));
    }

    #[test]
    fn div_by_zero_semantics() {
        let (cpu, _, _) = run(
            r#"
            li a0, 5
            li a1, 0
            div a2, a0, a1    # -1
            divu a3, a0, a1   # 0xFFFFFFFF
            rem a4, a0, a1    # 5
            ebreak
            "#,
        );
        assert_eq!(cpu.regs[12], u32::MAX);
        assert_eq!(cpu.regs[13], u32::MAX);
        assert_eq!(cpu.regs[14], 5);
    }

    #[test]
    fn mulh_variants() {
        let (cpu, _, _) = run(
            r#"
            li a0, -2
            li a1, 3
            mulh  a2, a0, a1    # high of -6 = -1
            mulhu a3, a0, a1    # high of (2^32-2)*3
            mulhsu a4, a0, a1   # high of -2 * 3 (unsigned b)
            ebreak
            "#,
        );
        assert_eq!(cpu.regs[12], 0xFFFF_FFFF);
        assert_eq!(cpu.regs[13], 2); // (2^32-2)*3 = 3*2^32 - 6
        assert_eq!(cpu.regs[14], 0xFFFF_FFFF);
    }

    #[test]
    fn memory_and_loops() {
        let (cpu, bus, _) = run(
            r#"
            .data
            arr: .word 5, 4, 3, 2, 1
            out: .word 0
            .text
            _start:
                la  t0, arr
                li  t1, 5       # count
                li  t2, 0       # sum
            loop:
                lw  t3, 0(t0)
                add t2, t2, t3
                addi t0, t0, 4
                addi t1, t1, -1
                bnez t1, loop
                la  t4, out
                sw  t2, 0(t4)
                ebreak
            "#,
        );
        assert_eq!(cpu.regs[7], 15);
        let out_addr = 0x0002_0014usize;
        assert_eq!(
            u32::from_le_bytes(bus.mem[out_addr..out_addr + 4].try_into().unwrap()),
            15
        );
    }

    #[test]
    fn byte_halfword_sign_extension() {
        let (cpu, _, _) = run(
            r#"
            .data
            b: .byte 0xFF
            .align 1
            h: .half 0x8000
            .text
            la t0, b
            lb t1, 0(t0)     # -1
            lbu t2, 0(t0)    # 255
            la t0, h
            lh t3, 0(t0)     # -32768
            lhu t4, 0(t0)    # 32768
            ebreak
            "#,
        );
        assert_eq!(cpu.regs[6] as i32, -1);
        assert_eq!(cpu.regs[7], 255);
        assert_eq!(cpu.regs[28] as i32, -32768);
        assert_eq!(cpu.regs[29], 32768);
    }

    #[test]
    fn misaligned_load_traps_to_halt_without_mtvec() {
        let (cpu, _, _) = run("li t0, 2\nlw t1, 0(t0)\nebreak");
        match cpu.state {
            CpuState::Halted(Halt::UnhandledTrap { cause, .. }) => {
                assert_eq!(cause, cause::LOAD_MISALIGNED);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn trap_handler_and_mret() {
        let (cpu, _, _) = run(
            r#"
            _start:
                la  t0, handler
                csrw mtvec, t0
                ecall              # -> handler
                li  a1, 99         # resumed here
                ebreak
            handler:
                csrr a0, mcause    # 11
                csrr t1, mepc
                addi t1, t1, 4
                csrw mepc, t1
                mret
            "#,
        );
        assert_eq!(cpu.regs[10], 11);
        assert_eq!(cpu.regs[11], 99);
    }

    #[test]
    fn wfi_sleeps_until_interrupt() {
        let prog = assemble(
            r#"
            _start:
                la  t0, handler
                ori t0, t0, 0      # direct mode
                csrw mtvec, t0
                li  t1, 0x80       # MTIP enable
                csrw mie, t1
                csrsi mstatus, 8   # MIE
                wfi
                li  a0, 1          # (not reached before irq)
                ebreak
            handler:
                li  a1, 7
                ebreak
            "#,
        )
        .unwrap();
        let mut bus = FlatBus::new(&prog);
        let mut cpu = Cpu::new(prog.entry);
        let mut now = 0u64;
        // run until sleeping
        while cpu.state == CpuState::Running {
            now += cpu.step(&mut bus, now).cycles as u64;
        }
        assert_eq!(cpu.state, CpuState::Sleeping);
        // no interrupt -> stays asleep
        now += cpu.step(&mut bus, now).cycles as u64;
        assert_eq!(cpu.state, CpuState::Sleeping);
        // assert timer irq
        cpu.set_irq_lines(true, 0);
        while !matches!(cpu.state, CpuState::Halted(_)) {
            now += cpu.step(&mut bus, now).cycles as u64;
        }
        assert_eq!(cpu.regs[11], 7); // handler ran
        assert_eq!(cpu.regs[10], 0); // straight-line code after wfi never ran
    }

    #[test]
    fn interrupt_priority_fast_over_timer() {
        let mut cpu = Cpu::new(0);
        cpu.csrs.mtvec = 0x100;
        cpu.csrs.write(crate::isa::csr::MIE, int::MTIP | int::fast(1)).then_some(()).unwrap();
        cpu.csrs.set_mie_global(true);
        cpu.set_irq_lines(true, 1 << 1);
        let prog = assemble("nop").unwrap();
        let mut bus = FlatBus::new(&prog);
        cpu.step(&mut bus, 0);
        assert_eq!(cpu.csrs.mcause, cause::interrupt(int::FAST_BASE + 1));
    }

    #[test]
    fn cycle_costs_accumulate() {
        let prog = assemble("li a0, 1\nmul a1, a0, a0\ndiv a2, a0, a0\nebreak").unwrap();
        let mut bus = FlatBus::new(&prog);
        let mut cpu = Cpu::new(prog.entry);
        let mut total = 0u64;
        while !matches!(cpu.state, CpuState::Halted(_)) {
            total += cpu.step(&mut bus, total).cycles as u64;
        }
        let t = Timing::default();
        assert_eq!(total, (t.alu + t.mul + t.div + t.alu) as u64);
    }

    #[test]
    fn retire_trace_counts_and_hashes() {
        let prog = assemble("li a0, 1\nli a1, 2\nebreak").unwrap();
        let mut bus = FlatBus::new(&prog);
        let mut cpu = Cpu::new(prog.entry);
        cpu.trace = Some(Box::default());
        let mut now = 0u64;
        while !matches!(cpu.state, CpuState::Halted(_)) {
            now += cpu.step(&mut bus, now).cycles as u64;
        }
        let t = cpu.trace.as_ref().unwrap();
        assert_eq!(t.count, 3); // two li + the retiring ebreak
        assert_ne!(t.hash, RetireTrace::default().hash);
        assert_eq!(t.recent_pcs(), vec![prog.entry, prog.entry + 4, prog.entry + 8]);
    }

    #[test]
    fn x0_stays_zero() {
        let (cpu, _, _) = run("li t0, 5\nadd x0, t0, t0\nsub a0, x0, t0\nebreak");
        assert_eq!(cpu.regs[0], 0);
        assert_eq!(cpu.regs[10] as i32, -5);
    }

    #[test]
    fn csr_read_write_cycle_counters() {
        let (cpu, _, _) = run(
            r#"
            csrr a0, mcycle
            csrr a1, minstret
            csrr a2, mhartid
            ebreak
            "#,
        );
        // minstret read at the second instruction sees 1 retired
        assert_eq!(cpu.regs[11], 1);
        assert_eq!(cpu.regs[12], 0);
        assert!(cpu.regs[10] < 10);
    }
}
