//! Machine-mode CSR file.

use crate::isa::csr;

/// mstatus bits we implement.
const MSTATUS_MIE: u32 = 1 << 3;
const MSTATUS_MPIE: u32 = 1 << 7;

#[derive(Clone, Debug, Default)]
pub struct Csrs {
    pub mstatus: u32,
    pub mie: u32,
    pub mip: u32,
    pub mtvec: u32,
    pub mscratch: u32,
    pub mepc: u32,
    pub mcause: u32,
    pub mtval: u32,
}

impl Csrs {
    pub fn new() -> Self {
        Self::default()
    }

    /// Global machine interrupt enable.
    pub fn mie_global(&self) -> bool {
        self.mstatus & MSTATUS_MIE != 0
    }

    pub fn set_mie_global(&mut self, on: bool) {
        if on {
            self.mstatus |= MSTATUS_MIE;
        } else {
            self.mstatus &= !MSTATUS_MIE;
        }
    }

    /// Trap entry: MPIE <- MIE, MIE <- 0.
    pub fn push_mie(&mut self) {
        let mie = self.mstatus & MSTATUS_MIE != 0;
        self.mstatus &= !(MSTATUS_MIE | MSTATUS_MPIE);
        if mie {
            self.mstatus |= MSTATUS_MPIE;
        }
    }

    /// MRET: MIE <- MPIE, MPIE <- 1.
    pub fn pop_mie(&mut self) {
        let mpie = self.mstatus & MSTATUS_MPIE != 0;
        self.mstatus |= MSTATUS_MPIE;
        if mpie {
            self.mstatus |= MSTATUS_MIE;
        } else {
            self.mstatus &= !MSTATUS_MIE;
        }
    }

    /// CSR read; `None` for unimplemented addresses (illegal instruction).
    pub fn read(&self, addr: u16, cycle: u64, instret: u64) -> Option<u32> {
        Some(match addr {
            csr::MSTATUS => self.mstatus,
            csr::MIE => self.mie,
            csr::MIP => self.mip,
            csr::MTVEC => self.mtvec,
            csr::MSCRATCH => self.mscratch,
            csr::MEPC => self.mepc,
            csr::MCAUSE => self.mcause,
            csr::MTVAL => self.mtval,
            csr::MCYCLE => cycle as u32,
            csr::MCYCLEH => (cycle >> 32) as u32,
            csr::MINSTRET => instret as u32,
            csr::MINSTRETH => (instret >> 32) as u32,
            csr::MHARTID => 0,
            _ => return None,
        })
    }

    /// CSR write; returns false for unimplemented/read-only addresses.
    pub fn write(&mut self, addr: u16, value: u32) -> bool {
        match addr {
            csr::MSTATUS => self.mstatus = value & (MSTATUS_MIE | MSTATUS_MPIE),
            csr::MIE => self.mie = value,
            // mip is hardware-driven in this model; writes are ignored but
            // legal (some firmware clears it defensively)
            csr::MIP => {}
            csr::MTVEC => self.mtvec = value,
            csr::MSCRATCH => self.mscratch = value,
            csr::MEPC => self.mepc = value & !1,
            csr::MCAUSE => self.mcause = value,
            csr::MTVAL => self.mtval = value,
            // cycle/instret are read-only in this core
            csr::MCYCLE | csr::MCYCLEH | csr::MINSTRET | csr::MINSTRETH | csr::MHARTID => {
                return false
            }
            _ => return false,
        }
        true
    }

    /// Does this core implement the CSR at all? Mirrors [`Self::read`]
    /// — the static analyzer ([`crate::analyze`]) uses these two query
    /// helpers so its CSR lint can never drift from the trap behavior.
    pub fn is_known(addr: u16) -> bool {
        matches!(
            addr,
            csr::MSTATUS
                | csr::MIE
                | csr::MIP
                | csr::MTVEC
                | csr::MSCRATCH
                | csr::MEPC
                | csr::MCAUSE
                | csr::MTVAL
                | csr::MCYCLE
                | csr::MCYCLEH
                | csr::MINSTRET
                | csr::MINSTRETH
                | csr::MHARTID
        )
    }

    /// Is the CSR read-only (a write traps)? Mirrors [`Self::write`];
    /// note `mip` is writable-but-ignored, i.e. *not* read-only.
    pub fn is_read_only(addr: u16) -> bool {
        matches!(
            addr,
            csr::MCYCLE | csr::MCYCLEH | csr::MINSTRET | csr::MINSTRETH | csr::MHARTID
        )
    }
}

impl Csrs {
    pub fn save_state(&self, w: &mut crate::snapshot::Writer) {
        w.u32(self.mstatus);
        w.u32(self.mie);
        w.u32(self.mip);
        w.u32(self.mtvec);
        w.u32(self.mscratch);
        w.u32(self.mepc);
        w.u32(self.mcause);
        w.u32(self.mtval);
    }

    pub fn restore_state(&mut self, r: &mut crate::snapshot::Reader) -> anyhow::Result<()> {
        self.mstatus = r.u32()?;
        self.mie = r.u32()?;
        self.mip = r.u32()?;
        self.mtvec = r.u32()?;
        self.mscratch = r.u32()?;
        self.mepc = r.u32()?;
        self.mcause = r.u32()?;
        self.mtval = r.u32()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mie_push_pop() {
        let mut c = Csrs::new();
        c.set_mie_global(true);
        c.push_mie();
        assert!(!c.mie_global());
        assert!(c.mstatus & MSTATUS_MPIE != 0);
        c.pop_mie();
        assert!(c.mie_global());
    }

    #[test]
    fn push_preserves_disabled_state() {
        let mut c = Csrs::new();
        c.push_mie(); // MIE was 0
        c.pop_mie();
        assert!(!c.mie_global());
    }

    #[test]
    fn counters_read_only() {
        let mut c = Csrs::new();
        assert!(!c.write(csr::MCYCLE, 5));
        assert!(!c.write(csr::MHARTID, 5));
        assert_eq!(c.read(csr::MCYCLE, 0x1_2345_6789, 0), Some(0x2345_6789));
        assert_eq!(c.read(csr::MCYCLEH, 0x1_2345_6789, 0), Some(1));
    }

    #[test]
    fn unknown_csr_rejected() {
        let mut c = Csrs::new();
        assert_eq!(c.read(0x7C0, 0, 0), None);
        assert!(!c.write(0x7C0, 1));
    }

    #[test]
    fn query_helpers_mirror_read_write() {
        let mut c = Csrs::new();
        for addr in 0u16..0x1000 {
            assert_eq!(
                Csrs::is_known(addr),
                c.read(addr, 0, 0).is_some(),
                "is_known({addr:#x}) drifted from read()"
            );
            let writable = c.write(addr, 0);
            assert_eq!(
                writable,
                Csrs::is_known(addr) && !Csrs::is_read_only(addr),
                "is_read_only({addr:#x}) drifted from write()"
            );
        }
    }

    #[test]
    fn mepc_aligned() {
        let mut c = Csrs::new();
        c.write(csr::MEPC, 0x1003);
        assert_eq!(c.mepc, 0x1002);
    }
}
