// debug: run candidate HLOs and compare against numpy-dumped expectations
fn main() -> anyhow::Result<()> {
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;
    for name in ["bitrev", "stage", "q15"] {
        let proto = xla::HloModuleProto::from_text_file(&format!("/tmp/dbg_{name}.hlo.txt"))
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow::anyhow!("{e}"))?;
        let x: Vec<i32> = (0..512).collect();
        let lit = xla::Literal::vec1(&x);
        let result = exe.execute::<xla::Literal>(&[lit]).map_err(|e| anyhow::anyhow!("{e}"))?[0][0]
            .to_literal_sync().map_err(|e| anyhow::anyhow!("{e}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow::anyhow!("{e}"))?;
        let got = out.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e}"))?;
        // read expected from .npy (skip 128-byte header-ish: parse minimal)
        let raw = std::fs::read(format!("/tmp/dbg_{name}_want.npy"))?;
        let hdr_len = u16::from_le_bytes([raw[8], raw[9]]) as usize + 10;
        let want: Vec<i32> = raw[hdr_len..].chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect();
        let ok = got == want;
        println!("{name}: {}", if ok { "MATCH" } else { "MISMATCH" });
        if !ok {
            println!("  got[0..16]  = {:?}", &got[..16]);
            println!("  want[0..16] = {:?}", &want[..16]);
        }
    }
    Ok(())
}
