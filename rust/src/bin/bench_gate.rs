//! CI bench-regression gate: compare fresh `BENCH_*.json` snapshots
//! against a committed baseline and fail on regressions.
//!
//! ```text
//! bench_gate <baseline.json> <BENCH_a.json> [<BENCH_b.json> ...]
//! bench_gate --update <baseline.json> <BENCH_a.json> ...   # regenerate
//! ```
//!
//! The baseline maps tracked metrics (`"<bench>/<result name>"`) to
//! wall-second ceilings plus a relative `tolerance`:
//!
//! ```json
//! {"tolerance": 0.15,
//!  "metrics": {"table1/render_markdown": 0.01, "fig4_acquisition/sweep_serial": 60.0}}
//! ```
//!
//! A metric regresses when `current > baseline * (1 + tolerance)`. A
//! tracked metric missing from the fresh results is also a failure —
//! the gate must not silently go blind when a bench is renamed. Extra
//! (untracked) results are reported but never gate. The CI job retries
//! once (re-measure) before declaring a regression real.

use std::process::ExitCode;

use anyhow::{bail, Context, Result};
use femu::util::Json;

/// One comparison outcome.
#[derive(Debug, PartialEq)]
enum Verdict {
    Pass { ratio: f64 },
    Regressed { ratio: f64 },
    Missing,
}

/// Collect `"<bench>/<name>" -> wall_s` from one BENCH json document.
fn collect_metrics(doc: &Json) -> Result<Vec<(String, f64)>> {
    let bench = doc.str_field("bench")?;
    let mut out = Vec::new();
    for r in doc.get("results")?.as_arr()? {
        out.push((format!("{bench}/{}", r.str_field("name")?), r.get("wall_s")?.as_f64()?));
    }
    Ok(out)
}

/// Compare fresh metrics against the baseline. Returns one verdict per
/// tracked metric, in baseline order.
fn compare(
    baseline: &Json,
    current: &[(String, f64)],
) -> Result<Vec<(String, f64, Verdict)>> {
    let tolerance = match baseline.opt("tolerance") {
        Some(t) => t.as_f64()?,
        None => 0.15,
    };
    if !(0.0..10.0).contains(&tolerance) {
        bail!("baseline tolerance {tolerance} out of range");
    }
    let metrics = baseline.get("metrics")?.as_obj()?;
    let mut out = Vec::new();
    for (key, limit) in metrics {
        let limit = limit.as_f64()?;
        let verdict = match current.iter().find(|(k, _)| k == key) {
            None => Verdict::Missing,
            Some((_, wall)) => {
                let ratio = wall / limit;
                if ratio > 1.0 + tolerance {
                    Verdict::Regressed { ratio }
                } else {
                    Verdict::Pass { ratio }
                }
            }
        };
        out.push((key.clone(), limit, verdict));
    }
    Ok(out)
}

fn load(path: &str) -> Result<Json> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    Json::parse(&text).with_context(|| format!("parsing {path}"))
}

fn run() -> Result<bool> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (update, paths) = match args.first().map(String::as_str) {
        Some("--update") => (true, &args[1..]),
        _ => (false, &args[..]),
    };
    if paths.len() < 2 {
        bail!(
            "usage: bench_gate [--update] <baseline.json> <BENCH_a.json> [<BENCH_b.json> ...]"
        );
    }
    let baseline_path = &paths[0];
    let mut current: Vec<(String, f64)> = Vec::new();
    for path in &paths[1..] {
        current.extend(collect_metrics(&load(path)?)?);
    }

    if update {
        // regenerate the baseline from the fresh results, keeping the
        // existing tolerance and the maintainers' _comment
        let old = load(baseline_path).ok();
        let tolerance = old
            .as_ref()
            .and_then(|b| b.opt("tolerance").and_then(|t| t.as_f64().ok()))
            .unwrap_or(0.15);
        let metrics =
            Json::Obj(current.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect());
        let mut fields = Vec::new();
        if let Some(comment) = old.as_ref().and_then(|b| b.opt("_comment")) {
            fields.push(("_comment", comment.clone()));
        }
        fields.push(("tolerance", Json::Num(tolerance)));
        fields.push(("metrics", metrics));
        let doc = Json::obj(fields);
        std::fs::write(baseline_path, format!("{doc}\n"))
            .with_context(|| format!("writing {baseline_path}"))?;
        println!("bench_gate: wrote {} metric(s) to {baseline_path}", current.len());
        return Ok(true);
    }

    let baseline = load(baseline_path)?;
    let verdicts = compare(&baseline, &current)?;
    let mut ok = true;
    println!("{:<40} {:>12} {:>12} {:>8}  verdict", "metric", "baseline_s", "current_s", "ratio");
    for (key, limit, verdict) in &verdicts {
        let wall = current.iter().find(|(k, _)| k == key).map(|(_, w)| *w);
        match verdict {
            Verdict::Pass { ratio } => {
                println!("{key:<40} {limit:>12.6} {:>12.6} {ratio:>8.2}  ok", wall.unwrap());
            }
            Verdict::Regressed { ratio } => {
                ok = false;
                println!(
                    "{key:<40} {limit:>12.6} {:>12.6} {ratio:>8.2}  REGRESSED",
                    wall.unwrap()
                );
            }
            Verdict::Missing => {
                ok = false;
                println!("{key:<40} {limit:>12.6} {:>12} {:>8}  MISSING", "-", "-");
            }
        }
    }
    for (key, wall) in &current {
        if !verdicts.iter().any(|(k, _, _)| k == key) {
            println!("{key:<40} {:>12} {wall:>12.6} {:>8}  (untracked)", "-", "-");
        }
    }
    if !ok {
        println!("bench_gate: FAIL (regressed or missing tracked metrics)");
    } else {
        println!("bench_gate: ok ({} tracked metric(s))", verdicts.len());
    }
    Ok(ok)
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("bench_gate: error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bench_doc(bench: &str, results: &[(&str, f64)]) -> Json {
        Json::obj(vec![
            ("bench", Json::from(bench)),
            (
                "results",
                Json::Arr(
                    results
                        .iter()
                        .map(|(n, w)| {
                            Json::obj(vec![("name", Json::from(*n)), ("wall_s", Json::Num(*w))])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    fn baseline(tolerance: f64, metrics: &[(&str, f64)]) -> Json {
        Json::obj(vec![
            ("tolerance", Json::Num(tolerance)),
            (
                "metrics",
                Json::Obj(
                    metrics.iter().map(|(k, v)| (k.to_string(), Json::Num(*v))).collect(),
                ),
            ),
        ])
    }

    #[test]
    fn within_tolerance_passes() {
        let current = collect_metrics(&bench_doc("table1", &[("render", 0.011)])).unwrap();
        let b = baseline(0.15, &[("table1/render", 0.010)]);
        let v = compare(&b, &current).unwrap();
        assert!(matches!(v[0].2, Verdict::Pass { .. }), "{v:?}");
    }

    #[test]
    fn regression_beyond_tolerance_fails() {
        let current = collect_metrics(&bench_doc("table1", &[("render", 0.020)])).unwrap();
        let b = baseline(0.15, &[("table1/render", 0.010)]);
        let v = compare(&b, &current).unwrap();
        assert!(matches!(v[0].2, Verdict::Regressed { .. }), "{v:?}");
    }

    #[test]
    fn synthetically_deflated_baseline_is_caught() {
        // the gate-works demonstration: feed a baseline claiming the
        // bench used to be 100x faster — the fresh measurement must trip
        // the gate
        let current =
            collect_metrics(&bench_doc("fig4_acquisition", &[("sweep_serial", 2.0)])).unwrap();
        let b = baseline(0.15, &[("fig4_acquisition/sweep_serial", 0.02)]);
        let v = compare(&b, &current).unwrap();
        match v[0].2 {
            Verdict::Regressed { ratio } => assert!(ratio > 90.0),
            ref other => panic!("expected regression, got {other:?}"),
        }
    }

    #[test]
    fn missing_tracked_metric_fails() {
        let current = collect_metrics(&bench_doc("table1", &[("render", 0.01)])).unwrap();
        let b = baseline(0.15, &[("table1/filtering", 0.01)]);
        let v = compare(&b, &current).unwrap();
        assert_eq!(v[0].2, Verdict::Missing);
    }

    #[test]
    fn untracked_metrics_never_gate() {
        let current = collect_metrics(&bench_doc("table1", &[("render", 9e9)])).unwrap();
        let b = baseline(0.15, &[]);
        let v = compare(&b, &current).unwrap();
        assert!(v.is_empty());
    }
}
