//! Configuration system: platform TOML files ("configurable" is in the
//! paper's title — bank counts, clock, timing model, energy calibration,
//! flash timing are all data, not code).
//!
//! A platform file looks like:
//!
//! ```toml
//! name = "x-heep-femu"
//! freq_hz = 20000000
//! energy_model = "femu"        # or "heepocrates"
//! backend = "interp"           # execution engine: interp | blocks
//!
//! [mem]
//! num_banks = 2
//! bank_size = 0x20000
//! cs_dram_size = 0x1000000
//!
//! [flash]
//! mode = "virtualized"          # or "physical"
//! size = 0x400000
//!
//! [timing]
//! div = 34
//! load = 2
//! # ... any cpu::Timing field
//!
//! [energy.cpu]                  # optional per-domain overrides (mW)
//! active = 1.9
//! clock_gated = 0.21
//! power_gated = 0.012
//! retention = 0.0
//!
//! [trace]                       # event tracing (DESIGN.md §13)
//! categories = "retire,irq"     # or "all" / "none" (default)
//! depth = 65536                 # ring capacity in events
//!
//! [profile]                     # guest profiler (DESIGN.md §14)
//! enabled = true                # default false: no buckets allocated
//! ```
//!
//! Missing keys fall back to the X-HEEP-FEMU defaults, so a config file
//! only states its deltas.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::cpu::Timing;
use crate::energy::{DomainPower, EnergyModel};
use crate::exec::BackendKind;
use crate::periph::FlashTiming;
use crate::soc::SocConfig;
use crate::util::toml::Doc;

/// Everything needed to build a platform instance.
#[derive(Clone, Debug)]
pub struct PlatformConfig {
    pub name: String,
    pub soc: SocConfig,
    pub timing: Timing,
    pub energy: EnergyModel,
}

impl Default for PlatformConfig {
    fn default() -> Self {
        Self {
            name: "x-heep-femu".into(),
            soc: SocConfig::default(),
            timing: Timing::default(),
            energy: EnergyModel::femu(),
        }
    }
}

impl PlatformConfig {
    /// Parse a platform TOML document.
    pub fn parse(text: &str) -> Result<Self> {
        let doc = Doc::parse(text)?;
        let mut cfg = PlatformConfig::default();
        cfg.name = doc.str_or("name", &cfg.name)?;

        let freq = doc.u64_or("freq_hz", cfg.soc.freq_hz)?;
        cfg.soc.freq_hz = freq;
        cfg.energy.freq_hz = freq;

        cfg.soc.num_banks = doc.u64_or("mem.num_banks", cfg.soc.num_banks as u64)? as usize;
        cfg.soc.bank_size = doc.u64_or("mem.bank_size", cfg.soc.bank_size as u64)? as u32;
        if !cfg.soc.bank_size.is_power_of_two() {
            bail!("mem.bank_size must be a power of two");
        }
        cfg.soc.cs_dram_size =
            doc.u64_or("mem.cs_dram_size", cfg.soc.cs_dram_size as u64)? as usize;
        cfg.soc.flash_size = doc.u64_or("flash.size", cfg.soc.flash_size as u64)? as usize;
        cfg.soc.flash_timing = match doc.str_or("flash.mode", "virtualized")?.as_str() {
            "virtualized" => FlashTiming::virtualized(),
            "physical" => FlashTiming::physical(),
            other => bail!("flash.mode `{other}` (want virtualized|physical)"),
        };
        cfg.soc.backend = BackendKind::parse(&doc.str_or("backend", cfg.soc.backend.name())?)?;

        // event tracing (off unless a category mask is given)
        cfg.soc.trace.mask =
            crate::trace::parse_categories(&doc.str_or("trace.categories", "none")?)?;
        cfg.soc.trace.depth =
            doc.u64_or("trace.depth", cfg.soc.trace.depth as u64)? as usize;

        // guest profiler (off by default: buckets only allocate on demand)
        cfg.soc.profile = doc.bool_or("profile.enabled", cfg.soc.profile)?;

        // timing overrides
        let t = &mut cfg.timing;
        t.alu = doc.u64_or("timing.alu", t.alu as u64)? as u32;
        t.mul = doc.u64_or("timing.mul", t.mul as u64)? as u32;
        t.div = doc.u64_or("timing.div", t.div as u64)? as u32;
        t.load = doc.u64_or("timing.load", t.load as u64)? as u32;
        t.store = doc.u64_or("timing.store", t.store as u64)? as u32;
        t.branch = doc.u64_or("timing.branch", t.branch as u64)? as u32;
        t.branch_taken_penalty =
            doc.u64_or("timing.branch_taken_penalty", t.branch_taken_penalty as u64)? as u32;
        t.jump = doc.u64_or("timing.jump", t.jump as u64)? as u32;
        t.csr = doc.u64_or("timing.csr", t.csr as u64)? as u32;
        t.trap_entry = doc.u64_or("timing.trap_entry", t.trap_entry as u64)? as u32;
        t.wake = doc.u64_or("timing.wake", t.wake as u64)? as u32;

        // energy calibration: named base + optional per-domain overrides
        let base = doc.str_or("energy_model", "femu")?;
        let mut energy = EnergyModel::by_name(&base)
            .ok_or_else(|| anyhow::anyhow!("unknown energy_model `{base}`"))?;
        energy.freq_hz = freq;
        for (domain, slot) in [
            ("cpu", 0usize),
            ("bus", 1),
            ("periph", 2),
            ("mem_bank", 3),
            ("cgra", 4),
        ] {
            let get = |field: &str, default: f64| -> Result<f64> {
                doc.f64_or(&format!("energy.{domain}.{field}"), default)
            };
            let current = match slot {
                0 => energy.cpu,
                1 => energy.bus,
                2 => energy.periph,
                3 => energy.mem_bank,
                _ => energy.cgra,
            };
            let updated = DomainPower::new(
                get("active", current.mw[0])?,
                get("clock_gated", current.mw[1])?,
                get("power_gated", current.mw[2])?,
                get("retention", current.mw[3])?,
            );
            match slot {
                0 => energy.cpu = updated,
                1 => energy.bus = updated,
                2 => energy.periph = updated,
                3 => energy.mem_bank = updated,
                _ => energy.cgra = updated,
            }
        }
        cfg.energy = energy;
        Ok(cfg)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading platform config {path:?}"))?;
        Self::parse(&text).with_context(|| format!("parsing platform config {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_without_file() {
        let cfg = PlatformConfig::default();
        assert_eq!(cfg.soc.num_banks, 2);
        assert_eq!(cfg.energy.name, "femu");
    }

    #[test]
    fn parse_full_overrides() {
        let cfg = PlatformConfig::parse(
            r#"
            name = "custom"
            freq_hz = 50_000_000
            energy_model = "heepocrates"
            backend = "blocks"
            [mem]
            num_banks = 4
            bank_size = 0x10000
            [flash]
            mode = "physical"
            [timing]
            div = 10
            [energy.cgra]
            active = 9.9
            "#,
        )
        .unwrap();
        assert_eq!(cfg.name, "custom");
        assert_eq!(cfg.soc.freq_hz, 50_000_000);
        assert_eq!(cfg.soc.num_banks, 4);
        assert_eq!(cfg.soc.flash_timing, FlashTiming::physical());
        assert_eq!(cfg.soc.backend, BackendKind::Blocks);
        assert_eq!(cfg.timing.div, 10);
        assert_eq!(cfg.timing.mul, Timing::default().mul); // untouched
        assert_eq!(cfg.energy.name, "heepocrates");
        assert_eq!(cfg.energy.cgra.mw[0], 9.9);
        assert_eq!(cfg.energy.freq_hz, 50_000_000);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(PlatformConfig::parse("[mem]\nbank_size = 1000").is_err()); // not pow2
        assert!(PlatformConfig::parse("[flash]\nmode = \"warp\"").is_err());
        assert!(PlatformConfig::parse("energy_model = \"mystery\"").is_err());
        assert!(PlatformConfig::parse("backend = \"jit\"").is_err());
        assert!(PlatformConfig::parse("[trace]\ncategories = \"vibes\"").is_err());
        assert!(PlatformConfig::parse("[profile]\nenabled = \"sure\"").is_err());
    }

    #[test]
    fn parse_profile_table() {
        let cfg = PlatformConfig::parse("[profile]\nenabled = true").unwrap();
        assert!(cfg.soc.profile);
        // default: profiler off
        let cfg = PlatformConfig::parse("").unwrap();
        assert!(!cfg.soc.profile);
    }

    #[test]
    fn parse_trace_table() {
        let cfg = PlatformConfig::parse(
            r#"
            [trace]
            categories = "retire,irq"
            depth = 1024
            "#,
        )
        .unwrap();
        use crate::trace::category;
        assert_eq!(cfg.soc.trace.mask, category::RETIRE | category::IRQ);
        assert_eq!(cfg.soc.trace.depth, 1024);
        // default: tracing off, default depth
        let cfg = PlatformConfig::parse("").unwrap();
        assert_eq!(cfg.soc.trace.mask, 0);
        assert_eq!(cfg.soc.trace.depth, crate::trace::DEFAULT_DEPTH);
    }

    #[test]
    fn empty_config_is_defaults() {
        let cfg = PlatformConfig::parse("").unwrap();
        assert_eq!(cfg.soc.bank_size, SocConfig::default().bank_size);
    }
}
