//! The emulated X-HEEP SoC: CPU + interconnect + CGRA + performance
//! monitor, with event-driven sleep and the CS hand-off points.
//!
//! This is the RH region of the FEMU split. [`Soc::run`] executes the
//! guest until it halts, exhausts the cycle budget, or needs the CS:
//! a mailbox doorbell (accelerator virtualization) or an ADC FIFO refill
//! (the software half of the dual-FIFO pacing). The coordinator
//! ([`crate::coordinator`]) services those and resumes — the exact
//! PL↔PS control flow of the paper, collapsed into one process.
//!
//! Power-state bookkeeping: the CPU domain is Active while running and
//! ClockGated in WFI; memory banks follow the guest-configured sleep
//! policy during WFI and explicit power-control writes otherwise; the
//! CGRA domain is Active exactly during its busy window. All transitions
//! are timestamped into the [`PerfMonitor`], which is what the energy
//! model integrates (§IV-C/D).

mod loader;

pub use loader::load_program;

use crate::bus::{Bus, BRIDGE_BASE, SRAM_BASE};
use crate::cgra::device::{kernel_id, LaunchRequest};
use crate::cgra::{kernels, CgraCore, CgraMem, CgraRun};
use crate::cpu::{int, Cpu, CpuState, Halt};
use crate::exec::{BackendKind, ExecBackend, ExecStats};
use crate::isa::Program;
use crate::mem::SramBank;
use crate::periph::gpio::GpioEvent;
use crate::periph::power::PowerRequest;
use crate::periph::{FlashTiming, SpiFlash};
use crate::perfmon::{Domain, PerfMonitor, PowerState};

/// Why [`Soc::run`] returned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunExit {
    /// Guest halted (ebreak or unhandled trap).
    Halted(Halt),
    /// Guest rang the mailbox doorbell; the CS accelerator service must
    /// handle the request block at this CS-DRAM byte offset.
    MailboxRing(u32),
    /// The ADC hardware FIFO wants more samples from the CS software FIFO.
    AdcRefill,
    /// Cycle budget exhausted.
    CycleBudget,
    /// Asleep with no pending or future wake-up source — a guest hang.
    DeadSleep,
}

/// Construction parameters (defaults mirror the X-HEEP-FEMU build).
#[derive(Clone, Debug)]
pub struct SocConfig {
    pub num_banks: usize,
    pub bank_size: u32,
    pub cs_dram_size: usize,
    pub flash_size: usize,
    pub flash_timing: FlashTiming,
    /// Emulated core clock (HEEPocrates runs 20 MHz @ 0.8 V).
    pub freq_hz: u64,
    /// Execution backend driving the core ([`crate::exec`]). Both
    /// backends are bit-identical by contract; `Blocks` trades compile
    /// time for guest throughput.
    pub backend: BackendKind,
    /// Event tracing ([`crate::trace`]). The default mask is 0: no ring
    /// is even allocated, so untraced runs pay nothing.
    pub trace: crate::trace::TraceConfig,
    /// Arm the guest profiler ([`crate::profile`]) at construction.
    /// Default off: no buckets are allocated and both backends pay a
    /// single never-taken branch per instruction.
    pub profile: bool,
}

impl Default for SocConfig {
    fn default() -> Self {
        Self {
            num_banks: 2,
            bank_size: 0x2_0000, // 128 KiB per bank
            cs_dram_size: 16 << 20,
            flash_size: 4 << 20,
            flash_timing: FlashTiming::virtualized(),
            freq_hz: 20_000_000,
            backend: BackendKind::Interp,
            trace: crate::trace::TraceConfig::default(),
            profile: false,
        }
    }
}

/// Run statistics beyond the perf counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct SocStats {
    pub instructions: u64,
    pub cgra_launches: u64,
    pub cgra_run: CgraRun,
    pub mailbox_rings: u64,
    pub dma_errors: u64,
}

pub struct Soc {
    pub cpu: Cpu,
    pub bus: Bus,
    pub cgra: CgraCore,
    pub perf: PerfMonitor,
    pub now: u64,
    pub freq_hz: u64,
    pub stats: SocStats,
    /// Bank states saved at WFI entry (sleep policy restore).
    saved_bank_states: Option<Vec<PowerState>>,
    /// Pending CGRA completion time (perf-domain restore).
    cgra_busy_until: Option<u64>,
    was_sleeping: bool,
    /// Sticky CGRA mapping fault (emulation diagnostics).
    pub cgra_fault: Option<crate::cgra::CgraFault>,
    /// The pluggable execution engine ([`crate::exec`]). `None` only
    /// while a `run` slice is in flight (the backend is taken out so it
    /// can borrow the SoC mutably) — always put back before returning.
    /// Not serialized: backends hold derived caches, no architectural
    /// state, so interp and block snapshots stay byte-comparable.
    backend: Option<Box<dyn ExecBackend>>,
}

impl Soc {
    pub fn new(cfg: SocConfig) -> Self {
        let flash = SpiFlash::new(cfg.flash_size, cfg.flash_timing);
        let mut soc = Self {
            cpu: Cpu::new(SRAM_BASE),
            bus: Bus::new(cfg.num_banks, cfg.bank_size, cfg.cs_dram_size, flash),
            cgra: CgraCore::new(),
            perf: PerfMonitor::new(cfg.num_banks),
            now: 0,
            freq_hz: cfg.freq_hz,
            stats: SocStats::default(),
            saved_bank_states: None,
            cgra_busy_until: None,
            was_sleeping: false,
            cgra_fault: None,
            backend: Some(cfg.backend.create()),
        };
        if cfg.trace.mask != 0 {
            soc.set_trace(cfg.trace);
        }
        if cfg.profile {
            soc.set_profile();
        }
        soc
    }

    /// Which execution backend drives this SoC.
    pub fn backend_kind(&self) -> BackendKind {
        self.backend.as_ref().map(|b| b.kind()).unwrap_or_default()
    }

    /// Swap the execution backend. Architectural state is untouched —
    /// backends only hold derived caches, so switching mid-run is safe.
    pub fn set_backend(&mut self, kind: BackendKind) {
        if self.backend_kind() != kind {
            self.backend = Some(kind.create());
        }
    }

    /// Backend-internal counters (block dispatches, rebuilds, …) for
    /// diagnostics and the self-modifying-code tests.
    pub fn exec_stats(&self) -> ExecStats {
        self.backend.as_ref().map(|b| b.exec_stats()).unwrap_or_default()
    }

    /// Warm the backend's derived caches for the given block-entry pcs
    /// (the static analyzer's block-map export, [`crate::analyze`]).
    /// A no-op on backends without caches; never changes results —
    /// `femu diff --precompile` proves it.
    pub fn precompile(&mut self, entries: &[u32]) {
        let mut backend = self.backend.take().expect("execution backend in use");
        backend.precompile(self, entries);
        self.backend = Some(backend);
    }

    /// The backend's current derived block view (empty for backends
    /// without block caches), for comparison against the statically
    /// recovered CFG.
    pub fn block_map(&self) -> Vec<crate::exec::BlockInfo> {
        self.backend.as_ref().map(|b| b.block_map()).unwrap_or_default()
    }

    /// Load a guest program and point the CPU at its entry (the debugger
    /// virtualization path does the same through [`crate::virt::debugger`]).
    pub fn load(&mut self, prog: &Program) -> anyhow::Result<()> {
        load_program(&mut self.bus, prog)?;
        self.cpu.reset(prog.entry);
        // memory changed wholesale under the backend: derived caches die
        if let Some(b) = &mut self.backend {
            b.restore_hook();
        }
        self.reset_trace();
        self.reset_profile();
        Ok(())
    }

    /// Drop recorded trace history and resync the IRQ baseline — used
    /// after any operation that rewrites the world underneath the ring
    /// (program load, snapshot restore), so replayed line levels are
    /// never double-reported as fresh edges (no phantom events).
    fn reset_trace(&mut self) {
        if self.bus.trace.is_some() {
            let lines = self.irq_lines_word();
            if let Some(t) = self.bus.trace.as_deref_mut() {
                t.clear();
                t.resync(lines);
            }
        }
    }

    /// Seconds represented by `cycles` at the emulated clock.
    pub fn secs(&self, cycles: u64) -> f64 {
        cycles as f64 / self.freq_hz as f64
    }

    // ---- event tracing --------------------------------------------------

    /// Install (or replace) the trace ring (DESIGN.md §13). Works with
    /// `mask == 0` too — the bench harness arms a silent ring to measure
    /// the trace-off overhead. The IRQ baseline is resynced to the
    /// current line state so installing mid-run fabricates no edges.
    pub fn set_trace(&mut self, cfg: crate::trace::TraceConfig) {
        let mut ring = Box::new(crate::trace::TraceRing::new(cfg));
        ring.resync(self.irq_lines_word());
        self.bus.trace = Some(ring);
    }

    /// The installed trace ring, if any.
    pub fn trace_ring(&self) -> Option<&crate::trace::TraceRing> {
        self.bus.trace.as_deref()
    }

    pub fn trace_ring_mut(&mut self) -> Option<&mut crate::trace::TraceRing> {
        self.bus.trace.as_deref_mut()
    }

    /// Remove the trace ring and return it (server `trace.stop` takes
    /// the final totals this way).
    pub fn take_trace(&mut self) -> Option<Box<crate::trace::TraceRing>> {
        self.bus.trace.take()
    }

    // ---- guest profiling ------------------------------------------------

    /// Install (or re-arm) the guest profiler (DESIGN.md §14): dense
    /// pc buckets over the SRAM span, with the capture window opening
    /// at the current cycle/pc/perf-counter state.
    pub fn set_profile(&mut self) {
        let span = self.bus.banks.len() as u32 * self.bus.bank_size;
        let baseline = self.perf.snapshot(self.now);
        self.bus.profile =
            Some(Box::new(crate::profile::Profiler::new(span, self.now, self.cpu.pc, baseline)));
    }

    /// The installed profiler, if any.
    pub fn profiler(&self) -> Option<&crate::profile::Profiler> {
        self.bus.profile.as_deref()
    }

    pub fn profiler_mut(&mut self) -> Option<&mut crate::profile::Profiler> {
        self.bus.profile.as_deref_mut()
    }

    /// Remove the profiler and return it (server `profile.stop` takes
    /// the final totals this way).
    pub fn take_profile(&mut self) -> Option<Box<crate::profile::Profiler>> {
        self.bus.profile.take()
    }

    /// Drop recorded profile history and reopen the window at the
    /// current cycle/pc with a fresh perf baseline — profile state is
    /// derived, like the trace ring: it never survives a program load
    /// or snapshot restore (no phantom samples).
    fn reset_profile(&mut self) {
        if self.bus.profile.is_some() {
            let baseline = self.perf.snapshot(self.now);
            let (now, pc) = (self.now, self.cpu.pc);
            if let Some(p) = self.bus.profile.as_deref_mut() {
                p.reset(now, pc, baseline);
            }
        }
    }

    /// Combined IRQ-line word in `mip` bit layout (bit 7 = machine
    /// timer, bits 16.. = fast lines) — the value the trace ring diffs
    /// on every refresh, so event `arg`s name real `mip` bits.
    fn irq_lines_word(&self) -> u32 {
        let mtip = self.bus.timer.irq_pending(self.now);
        let fast = self.bus.fast_irq_lines(self.now);
        ((mtip as u32) << 7) | (fast << int::FAST_BASE)
    }

    /// Power transition through the perf monitor, mirrored into the
    /// trace ring — but only on actual state *changes*, so the ring
    /// never records the no-op re-assertions the sleep paths emit.
    fn set_power(&mut self, d: Domain, s: PowerState, at: u64) {
        if self.perf.set_state(d, s, at) {
            if let Some(t) = self.bus.trace.as_deref_mut() {
                let idx = crate::perfmon::vcd::domain_index(d, self.bus.banks.len());
                t.power(at, idx as u16, s.to_u8());
            }
        }
    }

    // ---- event-driven execution ----------------------------------------

    pub(crate) fn refresh_irq_lines(&mut self) {
        let mtip = self.bus.timer.irq_pending(self.now);
        let fast = self.bus.fast_irq_lines(self.now);
        if let Some(t) = self.bus.trace.as_deref_mut() {
            t.irq_edges(self.now, ((mtip as u32) << 7) | (fast << int::FAST_BASE));
        }
        self.cpu.set_irq_lines(mtip, fast);
    }

    /// Earliest future device event (wake source while sleeping).
    pub(crate) fn next_event(&self) -> Option<u64> {
        let mut next: Option<u64> = None;
        let mut consider = |e: Option<u64>| {
            if let Some(t) = e {
                next = Some(next.map_or(t, |n| n.min(t)));
            }
        };
        consider(self.bus.timer.next_event(self.now));
        consider(self.bus.spi_adc.next_event(self.now));
        consider(self.bus.dma.next_event(self.now));
        consider(self.bus.cgra_dev.next_event(self.now));
        consider(self.bus.mailbox.next_event(self.now));
        next
    }

    /// First cycle at which a device event or CGRA completion becomes
    /// due. While `now` stays strictly below this (and no peripheral is
    /// touched), [`Soc::post_step`] is provably a no-op — the invariant
    /// both the sleep fast-forward and block dispatch rely on.
    pub(crate) fn event_horizon(&self) -> u64 {
        let e = self.next_event().unwrap_or(u64::MAX);
        e.min(self.cgra_busy_until.unwrap_or(u64::MAX))
    }

    /// Handle everything that may have happened after a CPU step or a
    /// sleep fast-forward.
    pub(crate) fn post_step(&mut self) {
        // Write-triggered work: only when a peripheral register was
        // actually written this step (§Perf opt 2 — the flag check keeps
        // the per-instruction overhead flat on compute-only code).
        if self.bus.periph_touched {
            self.bus.periph_touched = false;
            // GPIO edges: perf-monitor manual windows
            for ev in self.bus.gpio.take_events() {
                match ev {
                    GpioEvent::PerfWindowOpen => self.perf.window_open(self.now),
                    GpioEvent::PerfWindowClose => self.perf.window_close(self.now),
                }
            }
            // power-control requests
            for req in self.bus.power.take_requests() {
                match req {
                    PowerRequest::Bank(i, s) => {
                        self.bus.banks[i].set_state(s);
                        self.set_power(Domain::MemBank(i), s, self.now);
                    }
                    PowerRequest::Cgra(s) => {
                        // explicit CGRA state applies when not mid-run
                        if self.cgra_busy_until.is_none() {
                            self.set_power(Domain::Cgra, s, self.now);
                        }
                    }
                }
            }
            // CGRA launch service
            if let Some(req) = self.bus.cgra_dev.take_pending() {
                self.service_cgra_launch(req);
            }
        }
        // DMA completion: apply the copy transactionally (time-triggered)
        if let Some(req) = self.bus.dma.take_completed(self.now) {
            if self.apply_dma(req).is_err() {
                self.stats.dma_errors += 1;
            }
        }
        // CGRA completion: restore the domain to its configured state
        if let Some(t) = self.cgra_busy_until {
            if self.now >= t {
                self.cgra_busy_until = None;
                let s = self.bus.power.cgra_state();
                self.set_power(Domain::Cgra, s, t);
            }
        }
        self.bus.cgra_dev.tick(self.now);
        self.bus.mailbox.tick(self.now);
        self.bus.spi_adc.tick(self.now);

        // WFI domain transitions
        let sleeping = self.cpu.state == CpuState::Sleeping;
        if sleeping && !self.was_sleeping {
            self.enter_sleep();
        } else if !sleeping && self.was_sleeping {
            self.exit_sleep();
        }
        self.was_sleeping = sleeping;

        self.refresh_irq_lines();
    }

    fn enter_sleep(&mut self) {
        self.set_power(Domain::Cpu, PowerState::ClockGated, self.now);
        self.set_power(Domain::Bus, PowerState::ClockGated, self.now);
        self.set_power(Domain::Periph, PowerState::ClockGated, self.now);
        let mode = self.bus.power.sleep_mem_mode().as_power_state();
        if mode != PowerState::Active {
            let saved: Vec<PowerState> = self.bus.banks.iter().map(|b| b.state()).collect();
            for i in 0..self.bus.banks.len() {
                if self.bus.banks[i].state() == PowerState::Active {
                    self.bus.banks[i].set_state(mode);
                    self.set_power(Domain::MemBank(i), mode, self.now);
                }
            }
            self.saved_bank_states = Some(saved);
        }
    }

    fn exit_sleep(&mut self) {
        self.set_power(Domain::Cpu, PowerState::Active, self.now);
        self.set_power(Domain::Bus, PowerState::Active, self.now);
        self.set_power(Domain::Periph, PowerState::Active, self.now);
        if let Some(saved) = self.saved_bank_states.take() {
            for (i, s) in saved.into_iter().enumerate() {
                if s == PowerState::Active {
                    self.bus.banks[i].set_state(PowerState::Active);
                    self.set_power(Domain::MemBank(i), PowerState::Active, self.now);
                }
            }
        }
    }

    fn apply_dma(&mut self, req: crate::periph::dma::DmaRequest) -> Result<(), ()> {
        let words = (req.len as usize).div_ceil(4);
        for i in 0..words {
            let src = req.src + (i * 4) as u32;
            let dst = req.dst + (i * 4) as u32;
            let v = self.mem_read32(src)?;
            self.mem_write32(dst, v)?;
        }
        Ok(())
    }

    /// Word access honoring power states (DMA + CGRA master path).
    fn mem_read32(&mut self, addr: u32) -> Result<u32, ()> {
        if let Some(i) = self.bus.bank_index(addr) {
            let off = self.bus.bank_offset(addr);
            return self.bus.banks[i].read32(off).map_err(|_| ());
        }
        if addr >= BRIDGE_BASE {
            return self.bus.cs_dram.read32((addr - BRIDGE_BASE) as usize).map_err(|_| ());
        }
        Err(())
    }

    fn mem_write32(&mut self, addr: u32, v: u32) -> Result<(), ()> {
        if let Some(i) = self.bus.bank_index(addr) {
            let off = self.bus.bank_offset(addr);
            return self.bus.banks[i].write32(off, v).map_err(|_| ());
        }
        if addr >= BRIDGE_BASE {
            return self.bus.cs_dram.write32((addr - BRIDGE_BASE) as usize, v).map_err(|_| ());
        }
        Err(())
    }

    fn service_cgra_launch(&mut self, req: LaunchRequest) {
        let a = &req.args;
        let passes = match req.kernel {
            kernel_id::MATMUL => kernels::matmul_passes(
                a[0],
                a[1],
                a[2],
                a[3] as usize,
                a[4] as usize,
                a[5] as usize,
            ),
            kernel_id::CONV2D => kernels::conv2d_passes(
                a[0],
                a[1],
                a[2],
                a[3] as usize,
                a[4] as usize,
                a[5] as usize,
                a[6] as usize,
                a[7] as usize,
                a[8] as usize,
            ),
            kernel_id::FFT => kernels::fft_passes(a[0], a[1], a[2], a[3], a[4] as usize),
            _ => {
                // unknown kernel: complete immediately with zero cycles
                self.bus.cgra_dev.complete(CgraRun::default(), self.now);
                return;
            }
        };
        let mut view = BankView {
            banks: &mut self.bus.banks,
            bank_size: self.bus.bank_size,
            cs_dram: &mut self.bus.cs_dram,
        };
        let result = kernels::run_passes(&mut self.cgra, &passes, &mut view);
        match result {
            Ok(run) => {
                self.stats.cgra_launches += 1;
                self.stats.cgra_run.merge(run);
                // CGRA domain active for the duration of the run
                self.set_power(Domain::Cgra, PowerState::Active, self.now);
                self.cgra_busy_until = Some(self.now + run.total_cycles());
                self.bus.cgra_dev.complete(run, self.now);
            }
            Err(fault) => {
                self.cgra_fault = Some(fault);
                self.bus.cgra_dev.complete(CgraRun::default(), self.now);
            }
        }
    }

    /// Run until a CS hand-off point or `max_cycles` elapse. Delegates
    /// to the configured [`ExecBackend`] — the backend is taken out for
    /// the slice so it can borrow the SoC mutably, and always put back.
    pub fn run(&mut self, max_cycles: u64) -> RunExit {
        let mut backend = self.backend.take().expect("execution backend in use");
        let slice = backend.run_slice(self, max_cycles);
        self.backend = Some(backend);
        slice.exit
    }

    /// Convenience: run to halt, panicking on CS hand-offs (for guests
    /// that don't use virtualization services) and on budget exhaustion.
    pub fn run_to_halt(&mut self, max_cycles: u64) -> Halt {
        match self.run(max_cycles) {
            RunExit::Halted(h) => h,
            other => panic!("guest did not halt: {other:?} at cycle {}", self.now),
        }
    }

    /// Serialize the full SoC: clock, run stats, sleep bookkeeping, CPU,
    /// interconnect + devices, CGRA core, and perf counters. The
    /// execution backend contributes nothing (no architectural state),
    /// which is what keeps interp and block snapshots byte-comparable.
    pub fn save_state(&self, w: &mut crate::snapshot::Writer) {
        if let Some(b) = &self.backend {
            b.save_hook();
        }
        w.u64(self.now);
        w.u64(self.freq_hz);
        w.u64(self.stats.instructions);
        w.u64(self.stats.cgra_launches);
        w.u64(self.stats.cgra_run.compute_cycles);
        w.u64(self.stats.cgra_run.config_cycles);
        w.u64(self.stats.cgra_run.contexts);
        w.u64(self.stats.cgra_run.mem_stalls);
        w.u64(self.stats.mailbox_rings);
        w.u64(self.stats.dma_errors);
        match &self.saved_bank_states {
            None => w.bool(false),
            Some(states) => {
                w.bool(true);
                w.u32(states.len() as u32);
                for s in states {
                    w.u8(s.to_u8());
                }
            }
        }
        w.opt_u64(self.cgra_busy_until);
        w.bool(self.was_sleeping);
        match &self.cgra_fault {
            None => w.bool(false),
            Some(f) => {
                w.bool(true);
                w.u64(f.context_index);
                w.u64(f.pe as u64);
                w.u32(f.addr);
            }
        }
        self.cpu.save_state(w);
        self.bus.save_state(w);
        self.cgra.save_state(w);
        self.perf.save_state(w);
    }

    pub fn restore_state(&mut self, r: &mut crate::snapshot::Reader) -> anyhow::Result<()> {
        self.now = r.u64()?;
        self.freq_hz = r.u64()?;
        self.stats.instructions = r.u64()?;
        self.stats.cgra_launches = r.u64()?;
        self.stats.cgra_run = CgraRun {
            compute_cycles: r.u64()?,
            config_cycles: r.u64()?,
            contexts: r.u64()?,
            mem_stalls: r.u64()?,
        };
        self.stats.mailbox_rings = r.u64()?;
        self.stats.dma_errors = r.u64()?;
        self.saved_bank_states = if r.bool()? {
            let n = r.u32()? as usize;
            let mut states = Vec::with_capacity(n.min(1024));
            for _ in 0..n {
                states.push(PowerState::from_u8(r.u8()?)?);
            }
            Some(states)
        } else {
            None
        };
        self.cgra_busy_until = r.opt_u64()?;
        self.was_sleeping = r.bool()?;
        self.cgra_fault = if r.bool()? {
            let context_index = r.u64()?;
            let pe = r.u64()? as usize;
            let addr = r.u32()?;
            Some(crate::cgra::CgraFault { context_index, pe, addr })
        } else {
            None
        };
        self.cpu.restore_state(r)?;
        self.bus.restore_state(r)?;
        self.cgra.restore_state(r)?;
        self.perf.restore_state(r)?;
        // the memory image was replaced: compiled blocks are stale
        if let Some(b) = &mut self.backend {
            b.restore_hook();
        }
        // the ring and the profiler are derived state: never part of
        // the payload, always reset so a restored platform starts with
        // a clean capture (and a perf baseline matching the restored
        // counters — no phantom samples, no phantom energy)
        self.reset_trace();
        self.reset_profile();
        Ok(())
    }
}

/// CGRA master view over the SRAM banks + bridge window.
struct BankView<'a> {
    banks: &'a mut Vec<SramBank>,
    bank_size: u32,
    cs_dram: &'a mut crate::mem::CsDram,
}

impl BankView<'_> {
    #[inline]
    fn split(&self, addr: u32) -> (usize, usize) {
        let shift = self.bank_size.trailing_zeros();
        ((addr >> shift) as usize, (addr & (self.bank_size - 1)) as usize)
    }
}

impl CgraMem for BankView<'_> {
    fn read32(&mut self, addr: u32) -> Result<u32, ()> {
        let end = self.banks.len() as u32 * self.bank_size;
        if addr < end {
            let (i, off) = self.split(addr);
            return self.banks[i].read32(off).map_err(|_| ());
        }
        if addr >= BRIDGE_BASE {
            return self.cs_dram.read32((addr - BRIDGE_BASE) as usize).map_err(|_| ());
        }
        Err(())
    }

    fn write32(&mut self, addr: u32, value: u32) -> Result<(), ()> {
        let end = self.banks.len() as u32 * self.bank_size;
        if addr < end {
            let (i, off) = self.split(addr);
            return self.banks[i].write32(off, value).map_err(|_| ());
        }
        if addr >= BRIDGE_BASE {
            return self.cs_dram.write32((addr - BRIDGE_BASE) as usize, value).map_err(|_| ());
        }
        Err(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::assemble;

    fn soc_with(src: &str) -> Soc {
        let prog = assemble(src).expect("assemble");
        let mut soc = Soc::new(SocConfig::default());
        soc.load(&prog).unwrap();
        soc
    }

    #[test]
    fn runs_simple_program_to_halt() {
        let mut soc = soc_with(
            r#"
            _start:
                li a0, 5
                li a1, 7
                add a2, a0, a1
                ebreak
            "#,
        );
        assert_eq!(soc.run_to_halt(10_000), Halt::Ebreak);
        assert_eq!(soc.cpu.regs[12], 12);
        assert!(soc.stats.instructions >= 4);
    }

    #[test]
    fn uart_output_reaches_cs() {
        let mut soc = soc_with(
            r#"
            .equ UART_TX, 0x20000000
            _start:
                li t0, UART_TX
                li t1, 72        # 'H'
                sw t1, 0(t0)
                li t1, 105       # 'i'
                sw t1, 0(t0)
                ebreak
            "#,
        );
        soc.run_to_halt(10_000);
        assert_eq!(soc.bus.uart.drain(), b"Hi".to_vec());
    }

    #[test]
    fn wfi_timer_wakeup_counts_sleep_cycles() {
        let mut soc = soc_with(
            r#"
            .equ TIMER, 0x20000200
            _start:
                la  t0, handler
                csrw mtvec, t0
                li  t0, TIMER
                li  t1, 100000       # mtimecmp_lo
                sw  t1, 8(t0)
                sw  zero, 12(t0)     # mtimecmp_hi
                li  t1, 1
                sw  t1, 16(t0)       # irq enable
                li  t1, 0x80
                csrw mie, t1
                csrsi mstatus, 8
                wfi
                ebreak
            handler:
                ebreak
            "#,
        );
        soc.run_to_halt(1_000_000);
        // woke at ~100000
        assert!(soc.now >= 100_000 && soc.now < 100_200, "now={}", soc.now);
        let snap = soc.perf.snapshot(soc.now);
        let gated = snap.cpu.get(PowerState::ClockGated);
        assert!(gated > 99_000, "sleep cycles {gated}");
        assert!(snap.cpu.get(PowerState::Active) < 1_000);
    }

    #[test]
    fn sleep_mem_retention_policy() {
        let mut soc = soc_with(
            r#"
            .equ TIMER, 0x20000200
            .equ POWER, 0x20000600
            _start:
                la  t0, handler
                csrw mtvec, t0
                li  t0, POWER
                li  t1, 2            # retention during sleep
                sw  t1, 0(t0)
                li  t0, TIMER
                li  t1, 50000
                sw  t1, 8(t0)
                sw  zero, 12(t0)
                li  t1, 1
                sw  t1, 16(t0)
                li  t1, 0x80
                csrw mie, t1
                csrsi mstatus, 8
                wfi
                ebreak
            handler:
                # memory must be usable again after wake
                la  t2, marker
                lw  t3, 0(t2)
                ebreak
            .data
            marker: .word 1234
            "#,
        );
        soc.run_to_halt(1_000_000);
        assert_eq!(soc.cpu.regs[28], 1234); // retention preserved data
        let snap = soc.perf.snapshot(soc.now);
        assert!(snap.banks[1].get(PowerState::Retention) > 40_000);
        assert_eq!(soc.bus.banks[1].state(), PowerState::Active); // restored
    }

    #[test]
    fn dma_memcpy() {
        let mut soc = soc_with(
            r#"
            .equ DMA, 0x20000500
            _start:
                la  t0, src
                la  t1, dst
                li  t2, DMA
                sw  t0, 0(t2)      # SRC
                sw  t1, 4(t2)      # DST
                li  t3, 12
                sw  t3, 8(t2)      # LEN
                li  t3, 1
                sw  t3, 12(t2)     # CTRL: start
            wait:
                lw  t4, 16(t2)     # STATUS
                andi t4, t4, 1
                beqz t4, wait
                la  t1, dst
                lw  a0, 0(t1)
                lw  a1, 4(t1)
                lw  a2, 8(t1)
                ebreak
            .data
            src: .word 11, 22, 33
            dst: .word 0, 0, 0
            "#,
        );
        soc.run_to_halt(100_000);
        assert_eq!(soc.cpu.regs[10], 11);
        assert_eq!(soc.cpu.regs[11], 22);
        assert_eq!(soc.cpu.regs[12], 33);
    }

    #[test]
    fn cgra_matmul_launch_from_guest() {
        // 4x4 identity times vector via CGRA control port
        let mut soc = soc_with(
            r#"
            .equ CGRA, 0x20000700
            _start:
                li  t0, CGRA
                sw  zero, 8(t0)    # KERNEL = MATMUL
                la  t1, a
                sw  t1, 0x40(t0)   # ARG0 = a
                la  t1, b
                sw  t1, 0x44(t0)   # ARG1 = b
                la  t1, c
                sw  t1, 0x48(t0)   # ARG2 = c
                li  t1, 4
                sw  t1, 0x4C(t0)   # m
                sw  t1, 0x50(t0)   # k
                sw  t1, 0x54(t0)   # n
                li  t1, 1
                sw  t1, 4(t0)      # START
            wait:
                lw  t2, 0(t0)
                andi t2, t2, 1
                beqz t2, wait
                la  t3, c
                lw  a0, 0(t3)      # c[0,0]
                lw  a1, 20(t3)     # c[1,1]
                ebreak
            .data
            a:  .word 1, 0, 0, 0
                .word 0, 2, 0, 0
                .word 0, 0, 3, 0
                .word 0, 0, 0, 4
            b:  .word 1, 1, 1, 1
                .word 1, 1, 1, 1
                .word 1, 1, 1, 1
                .word 1, 1, 1, 1
            c:  .space 64
            "#,
        );
        soc.run_to_halt(1_000_000);
        assert_eq!(soc.cpu.regs[10], 1);
        assert_eq!(soc.cpu.regs[11], 2);
        assert_eq!(soc.stats.cgra_launches, 1);
        assert!(soc.cgra_fault.is_none());
        // CGRA domain saw active time
        let snap = soc.perf.snapshot(soc.now);
        assert!(snap.cgra.get(PowerState::Active) > 0);
    }

    #[test]
    fn mailbox_ring_surfaces_to_coordinator() {
        let mut soc = soc_with(
            r#"
            .equ MBOX, 0x20000800
            _start:
                li  t0, MBOX
                li  t1, 0x100
                sw  t1, 12(t0)     # REQ_OFF
                li  t1, 1
                sw  t1, 0(t0)      # DOORBELL
                ebreak
            "#,
        );
        match soc.run(100_000) {
            RunExit::MailboxRing(off) => assert_eq!(off, 0x100),
            other => panic!("{other:?}"),
        }
        assert_eq!(soc.stats.mailbox_rings, 1);
    }

    #[test]
    fn dead_sleep_detected() {
        let mut soc = soc_with("_start: wfi\nebreak");
        assert_eq!(soc.run(100_000), RunExit::DeadSleep);
    }

    #[test]
    fn budget_exhaustion() {
        let mut soc = soc_with("_start: j _start");
        assert_eq!(soc.run(1_000), RunExit::CycleBudget);
        assert!(soc.now >= 1_000);
    }

    #[test]
    fn perf_manual_window_via_gpio() {
        let mut soc = soc_with(
            r#"
            .equ GPIO, 0x20000100
            _start:
                li  t0, GPIO
                li  t1, 0x10000   # PERF bit
                sw  t1, 0(t0)     # open window
                li  t2, 100
            loop:
                addi t2, t2, -1
                bnez t2, loop
                sw  zero, 0(t0)   # close window
                ebreak
            "#,
        );
        soc.run_to_halt(100_000);
        let w = soc.perf.window_snapshot().expect("window recorded");
        assert!(w.cycles > 300 && w.cycles < 1_000, "{}", w.cycles);
    }
}
