//! Guest program loader: places assembled text/data into the SRAM banks.
//!
//! Used both at SoC construction and by the debugger virtualization's
//! reprogramming path (§III-A: "seamless reprogramming ... directly from
//! a script").

use anyhow::{Context, Result};

use crate::bus::{Bus, SRAM_BASE};
use crate::isa::Program;

/// Copy `bytes` into SRAM starting at `addr`, spanning banks as needed.
/// Ignores bank power states (debugger path powers banks implicitly).
/// Out-of-window loads are rejected with the offending address range
/// (the same [`crate::bus::MemoryMap`] check `femu analyze` lints with).
pub fn load_bytes(bus: &mut Bus, addr: u32, bytes: &[u8]) -> Result<()> {
    let bank_size = bus.bank_size as usize;
    bus.memory_map()
        .check_sram_span(addr, bytes.len())
        .with_context(|| format!("loading {} bytes", bytes.len()))?;
    let start = (addr - SRAM_BASE) as usize;
    let mut off = start;
    let mut rest = bytes;
    while !rest.is_empty() {
        let bank = off / bank_size;
        let in_bank = off % bank_size;
        let n = (bank_size - in_bank).min(rest.len());
        bus.banks[bank]
            .load(in_bank, &rest[..n])
            .map_err(|e| anyhow::anyhow!("bank {bank} load: {e:?}"))?;
        off += n;
        rest = &rest[n..];
    }
    Ok(())
}

/// Load an assembled program (text + data sections).
pub fn load_program(bus: &mut Bus, prog: &Program) -> Result<()> {
    let text_bytes: Vec<u8> = prog.text.iter().flat_map(|w| w.to_le_bytes()).collect();
    load_bytes(bus, prog.text_base, &text_bytes)?;
    if !prog.data.is_empty() {
        load_bytes(bus, prog.data_base, &prog.data)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::periph::{FlashTiming, SpiFlash};

    fn bus() -> Bus {
        Bus::new(2, 0x100, 1 << 16, SpiFlash::new(1 << 12, FlashTiming::virtualized()))
    }

    #[test]
    fn load_spans_banks() {
        let mut b = bus();
        let bytes: Vec<u8> = (0..=255).collect();
        // 256 bytes starting 0x80: crosses the 0x100 bank boundary
        load_bytes(&mut b, 0x80, &bytes).unwrap();
        assert_eq!(b.debug_read32(0x80).unwrap(), u32::from_le_bytes([0, 1, 2, 3]));
        assert_eq!(
            b.debug_read32(0x100).unwrap(),
            u32::from_le_bytes([128, 129, 130, 131])
        );
    }

    #[test]
    fn oversize_load_rejected_with_offending_range() {
        let mut b = bus();
        let bytes = vec![0u8; 0x300];
        let err = load_bytes(&mut b, 0, &bytes).unwrap_err();
        let msg = format!("{err:#}");
        // the error names the offending range and the actual window
        assert!(msg.contains("0x00000000..0x00000300"), "{msg}");
        assert!(msg.contains("outside SRAM"), "{msg}");
        assert!(msg.contains("0x00000200"), "{msg}");
    }

    #[test]
    fn program_load_places_sections() {
        let mut b = bus();
        let prog = crate::isa::assemble_with(
            ".data\nv: .word 0xAABBCCDD\n.text\n_start: nop",
            crate::isa::asm::Options { text_base: 0, data_base: 0x100 },
        )
        .unwrap();
        load_program(&mut b, &prog).unwrap();
        assert_eq!(b.debug_read32(0x100).unwrap(), 0xAABB_CCDD);
        assert_eq!(b.debug_read32(0).unwrap(), 0x0000_0013); // nop
    }
}
