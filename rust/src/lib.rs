// The emulator is safe Rust throughout, with no exceptions: the one
// historical `unsafe impl Send` (server sessions moving platforms between
// pool threads) was audited away — `Platform` is `Send` in safe Rust
// because [`exec::ExecBackend`] carries `Send` as a supertrait; a
// compile-time assertion in `server::session` keeps it that way.
#![deny(unsafe_code)]

pub mod analyze;
pub mod bridge;
pub mod bus;
pub mod cgra;
pub mod config;
pub mod coordinator;
pub mod cpu;
pub mod energy;
pub mod exec;
pub mod faults;
pub mod isa;
pub mod mem;
pub mod metrics;
pub mod perfmon;
pub mod periph;
pub mod profile;
pub mod runtime;
pub mod server;
pub mod snapshot;
pub mod soc;
pub mod trace;
pub mod util;
pub mod virt;
pub mod workloads;

/// The types almost every embedder needs: build a [`Platform`] from a
/// [`PlatformConfig`], run guests, pick an execution backend, sweep the
/// paper's experiments across a [`Fleet`], snapshot/restore, and talk to
/// a control server. `use femu::prelude::*;` — examples and benches use
/// this instead of spelling out a dozen module paths.
pub mod prelude {
    pub use crate::analyze::{self, AnalyzeConfig, Report};
    pub use crate::config::PlatformConfig;
    pub use crate::coordinator::{experiments, AppExit, Fleet, Platform};
    pub use crate::energy::{EnergyModel, EnergyReport};
    pub use crate::exec::{
        diff::{self, LockstepOptions, LockstepReport},
        BackendKind, ExecBackend, ExecStats, SliceResult,
    };
    pub use crate::faults::{CampaignReport, CampaignSpec, Outcome};
    pub use crate::perfmon::PerfSnapshot;
    pub use crate::server::{Client, Server};
    pub use crate::snapshot::PlatformSnapshot;
    pub use crate::soc::{RunExit, Soc, SocConfig};
    pub use crate::trace::{format::TraceDump, TraceConfig, TraceRing};
}
