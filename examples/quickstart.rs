//! Quickstart: assemble a guest program, run it on the emulated
//! X-HEEP-FEMU platform, and read back performance + energy estimates.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use femu::config::PlatformConfig;
use femu::coordinator::Platform;
use femu::energy::EnergyModel;

fn main() -> anyhow::Result<()> {
    // 1. Build a platform (the default config mirrors X-HEEP-FEMU:
    //    2 x 128 KiB SRAM banks, 20 MHz, femu energy calibration).
    let mut platform = Platform::new(PlatformConfig::default());

    // 2. Load a guest program through debugger virtualization. This one
    //    sums an array and prints a marker over the UART.
    let prog = platform.dbg.load_source(
        r#"
        .equ UART, 0x20000000
        _start:
            la   t0, data
            li   t1, 8          # length
            li   t2, 0          # sum
        loop:
            lw   t3, 0(t0)
            add  t2, t2, t3
            addi t0, t0, 4
            addi t1, t1, -1
            bnez t1, loop
            li   t4, UART
            li   t5, 79         # 'O'
            sw   t5, 0(t4)
            li   t5, 75         # 'K'
            sw   t5, 0(t4)
            ebreak
        .data
        data: .word 1, 2, 3, 4, 5, 6, 7, 8
        "#,
    )?;
    println!("loaded {} instructions, entry {:#x}", prog.text.len(), prog.entry);

    // 3. Run to completion.
    let exit = platform.run_app(1_000_000)?;
    println!("guest exit: {exit:?}");
    println!("uart: {:?}", String::from_utf8_lossy(&platform.dbg.uart()));

    // 4. Inspect guest state (debugger virtualization).
    let sum = platform.dbg.reg(7); // t2
    println!("sum register t2 = {sum}");
    assert_eq!(sum, 36);

    // 5. Performance counters + energy estimation (automatic mode).
    let snap = platform.perf_snapshot();
    println!("\ncycles: {} ({:.1} us at 20 MHz)", snap.cycles, snap.cycles as f64 / 20.0);
    for model in [EnergyModel::femu(), EnergyModel::heepocrates()] {
        let r = model.estimate(&snap);
        println!(
            "energy [{}]: {:.6} uJ total ({:.6} uJ active, {:.6} uJ sleep)",
            model.name,
            r.total_mj * 1e3,
            r.active_mj * 1e3,
            r.sleep_mj * 1e3,
        );
    }
    println!("\nquickstart OK");
    Ok(())
}
