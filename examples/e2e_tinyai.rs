//! End-to-end TinyAI driver — the full-system workload that proves all
//! layers compose (DESIGN.md §5 "V"):
//!
//! guest (RV32 on the emulated X-HEEP) acquires a 512-sample window from
//! the **virtualized ADC** (dual-FIFO pacing) → copies it through the
//! **bridge window** into the mailbox request block → rings the doorbell
//! → the CS **accelerator-virtualization** service executes the
//! `model` artifact (Pallas FFT kernel + Q15 classifier, AOT-lowered to
//! HLO, run via PJRT) → the guest reads the logits, computes the argmax,
//! and prints the class over the **UART** — while the perf monitor and
//! energy model price the whole run.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_tinyai
//! ```

use femu::config::PlatformConfig;
use femu::coordinator::Platform;
use femu::energy::EnergyModel;
use femu::runtime::TensorI32;
use femu::util::Rng;
use femu::workloads::{programs, signals};

const N: usize = 512;
const N_CLASSES: usize = 4;
const REQ_OFF: u32 = 0x1000;
const SAMPLE_RATE_HZ: f64 = 20_000.0;

fn main() -> anyhow::Result<()> {
    let cfg = PlatformConfig::default();
    let mut platform = Platform::new(cfg.clone());
    platform.attach_artifacts("artifacts")?;

    // CS-side model parameters (Q15 classifier weights), bound to the
    // `model` artifact entry — the guest never sees them.
    let mut rng = Rng::new(0xE2E);
    let w1 = TensorI32::new(vec![64, 32], rng.vec_i32(64 * 32, -(1 << 14), 1 << 14))?;
    let b1 = TensorI32::new(vec![32], rng.vec_i32(32, -500, 500))?;
    let w2 = TensorI32::new(vec![32, N_CLASSES], rng.vec_i32(32 * N_CLASSES, -(1 << 14), 1 << 14))?;
    let b2 = TensorI32::new(vec![N_CLASSES], rng.vec_i32(N_CLASSES, -500, 500))?;
    let params = vec![w1, b1, w2, b2];

    // expected result, computed through the same artifact (oracle check
    // against ref.py happens in the Python test suite)
    let sig = signals::biosignal(0x51_6, N, SAMPLE_RATE_HZ);
    let expected_logits = {
        let accel = platform.accel.as_ref().unwrap();
        let window = TensorI32::new(vec![N], sig.samples.clone())?;
        let mut args = vec![window];
        args.extend(params.iter().cloned());
        args.extend(femu::virt::accel::fft_table_tensors(N));
        accel.runtime().execute("model", &args)?[0].clone()
    };
    let expected_class = expected_logits
        .data()
        .iter()
        .enumerate()
        .max_by_key(|(_, &v)| v)
        .map(|(i, _)| i)
        .unwrap();

    platform.accel.as_mut().unwrap().bind_params("model", params);

    // guest program + ADC stream
    platform.dbg.load_source(&programs::classifier_mailbox(N, N_CLASSES, REQ_OFF))?;
    platform.start_adc(sig.samples.clone(), SAMPLE_RATE_HZ);

    println!("running end-to-end TinyAI app (acquire -> classify -> report)...");
    let exit = platform.run_app(1 << 34)?;
    println!("guest exit: {exit:?}");

    // UART report: 'C' + class, newline
    let uart = platform.dbg.uart();
    let printed = String::from_utf8_lossy(&uart);
    println!("uart: {printed:?}");
    let printed_class = (uart[0] - b'C') as usize;
    println!("guest-reported class: {printed_class}, CS-expected class: {expected_class}");
    assert_eq!(printed_class, expected_class, "guest argmax must match the artifact");

    // logits in the mailbox block must equal the direct execution
    let logits = platform
        .dbg
        .soc
        .bus
        .cs_dram
        .read_i32_slice(REQ_OFF as usize + 8 + N * 4, N_CLASSES)
        .map_err(|e| anyhow::anyhow!("reading logits: {e:?}"))?;
    assert_eq!(logits.as_slice(), expected_logits.data());
    println!("logits: {logits:?}");

    // whole-run performance + energy (acquisition is the dominant phase)
    let snap = platform.perf_snapshot();
    println!("\ntotal: {} cycles = {:.3} ms emulated", snap.cycles, snap.cycles as f64 / 20e3);
    for model in [EnergyModel::femu(), EnergyModel::heepocrates()] {
        let r = model.estimate(&snap);
        println!(
            "energy [{}]: {:.4} mJ (active {:.4}, sleep {:.4}), avg {:.3} mW",
            model.name,
            r.total_mj,
            r.active_mj,
            r.sleep_mj,
            r.avg_power_mw(),
        );
    }
    assert!(!platform.dbg.soc.bus.spi_adc.underrun());
    println!("\ne2e_tinyai OK");
    Ok(())
}
