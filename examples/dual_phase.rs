//! Dual-phase TinyAI application: **overlapping acquisition and
//! processing** (paper §I: applications "generally involve two distinct,
//! possibly overlapping, operational phases ... acquisition ... and
//! processing").
//!
//! The guest acquires sample windows from the virtualized ADC with an
//! **interrupt handler** (background phase) while the main loop runs the
//! Q15 FFT over the previously captured window (foreground phase) —
//! classic double buffering. The driver then quantifies what the overlap
//! buys: total time vs. the sequential acquire-then-process structure,
//! with full energy accounting.
//!
//! ```sh
//! cargo run --release --example dual_phase
//! ```

use femu::config::PlatformConfig;
use femu::coordinator::{Fleet, Platform};
use femu::energy::EnergyModel;
use femu::workloads::{programs, reference as refimpl, signals};

const N: usize = 512; // samples per window (FFT size)
const WINDOWS: usize = 4;
const RATE_HZ: f64 = 10_000.0;

/// Guest program: IRQ-driven acquisition into the fill buffer while the
/// main loop FFTs the previous window in place (double buffering).
fn dual_phase_program() -> String {
    format!(
        r#"{prelude}
.equ N, {n}
.equ WINDOWS, {windows}
_start:
    li   sp, 0x3F000         # stack in bank 1
    la   t0, handler
    csrw mtvec, t0
    la   t0, irq_save
    csrw mscratch, t0        # handler scratch base (mscratch swap idiom)
    li   t0, MIE_ADC
    csrw mie, t0
    li   s0, SPI_ADC
    li   t0, 3               # enable + irq
    sw   t0, 0(s0)
    # acquire window 0 in the foreground (nothing to process yet)
    la   t0, buf0
    la   t1, fill_ptr
    sw   t0, 0(t1)
    la   t1, fill_cnt
    sw   zero, 0(t1)
    csrsi mstatus, 8         # global irq enable: handler may run anywhere
wait_w0:
    la   t1, fill_cnt
    lw   t2, 0(t1)
    li   t3, N
    bgeu t2, t3, w0_done
    wfi
    j    wait_w0
w0_done:
    # main pipeline: for w in 1..WINDOWS: start acquiring into the other
    # buffer (irq-driven), FFT the window just captured, wait for fill.
    li   s10, 1              # w
    la   s8, buf0            # proc buffer (just filled)
    la   s9, buf1            # fill buffer
pipe:
    # arm background fill of s9
    la   t1, fill_ptr
    sw   s9, 0(t1)
    la   t1, fill_cnt
    sw   zero, 0(t1)
    # foreground: FFT(s8) — interrupts keep firing during this
    mv   a0, s8
    call fft512
    # wait for the background fill to finish
fill_wait:
    la   t1, fill_cnt
    lw   t2, 0(t1)
    li   t3, N
    bgeu t2, t3, fill_done
    wfi
    j    fill_wait
fill_done:
    # swap buffers, next window
    mv   t0, s8
    mv   s8, s9
    mv   s9, t0
    addi s10, s10, 1
    li   t0, WINDOWS
    bltu s10, t0, pipe
    # final window: process in the foreground
    mv   a0, s8
    call fft512
    ebreak

# ---- ADC IRQ handler: pop one sample into the fill buffer ----
# May preempt any code (including mid-FFT), so it must preserve every
# register it touches; ra is borrowed through the mscratch swap idiom.
handler:
    csrrw x1, mscratch, x1   # x1 <- irq_save base, mscratch <- caller ra
    sw   t0, 0(x1)
    sw   t1, 4(x1)
    sw   t2, 8(x1)
    li   t0, SPI_ADC
    lw   t1, 8(t0)           # RXDATA (costs the SPI word time)
    la   t0, fill_ptr
    lw   t2, 0(t0)
    sw   t1, 0(t2)
    addi t2, t2, 4
    sw   t2, 0(t0)
    la   t0, fill_cnt
    lw   t2, 0(t0)
    addi t2, t2, 1
    sw   t2, 0(t0)
    lw   t0, 0(x1)
    lw   t1, 4(x1)
    lw   t2, 8(x1)
    csrrw x1, mscratch, x1   # restore ra + re-arm the scratch base
    mret

# ---- in-place Q15 FFT over the window at a0 (re only; im = scratch) ----
# clobbers t*, a*, s1..s7, s11; preserves s8, s9, s10 (pipeline state)
fft512:
    la   s1, im_buf
    li   t0, 0
clr_im:
    slli t1, t0, 2
    add  t2, s1, t1
    sw   zero, 0(t2)
    addi t0, t0, 1
    li   t1, N
    bltu t0, t1, clr_im
    mv   s0, a0              # re base
    la   s2, rev_tbl
    li   t0, 0
bitrev_loop:
    slli t1, t0, 2
    add  t2, s2, t1
    lw   t3, 0(t2)
    ble  t3, t0, brskip
    slli t4, t3, 2
    add  t5, s0, t1
    add  t6, s0, t4
    lw   a1, 0(t5)
    lw   a2, 0(t6)
    sw   a2, 0(t5)
    sw   a1, 0(t6)
brskip:
    addi t0, t0, 1
    li   t1, N
    bltu t0, t1, bitrev_loop
    la   s2, wr_tbl
    la   s3, wi_tbl
    li   s5, 2
    li   a6, N
    srli a7, a6, 1           # stride = N/m (walks down per stage)
stage_loop:
    srli s6, s5, 1
    li   s7, 0
grp_loop:
    li   s11, 0              # j
j_loop:
    add  t0, s7, s11         # e
    add  t1, t0, s6          # o
    mul  t2, s11, a7         # tw
    slli t0, t0, 2
    slli t1, t1, 2
    slli t2, t2, 2
    add  a0, s0, t0
    add  a1, s1, t0
    add  a2, s0, t1
    add  a3, s1, t1
    add  a4, s2, t2
    add  a5, s3, t2
    lw   t3, 0(a2)
    lw   t4, 0(a3)
    lw   t5, 0(a4)
    lw   t6, 0(a5)
    mul  t0, t3, t5
    mulh t1, t3, t5
    srli t0, t0, 15
    slli t1, t1, 17
    or   t0, t0, t1          # q15(or*twr)
    mul  t1, t4, t6
    mulh t2, t4, t6
    srli t1, t1, 15
    slli t2, t2, 17
    or   t1, t1, t2          # q15(oi*twi)
    sub  t0, t0, t1          # tr
    mul  t1, t3, t6
    mulh t2, t3, t6
    srli t1, t1, 15
    slli t2, t2, 17
    or   t1, t1, t2          # q15(or*twi)
    mul  t3, t4, t5
    mulh t4, t4, t5
    srli t3, t3, 15
    slli t4, t4, 17
    or   t3, t3, t4          # q15(oi*twr)
    add  t1, t1, t3          # ti
    lw   t5, 0(a0)
    lw   t6, 0(a1)
    add  t3, t5, t0
    srai t3, t3, 1
    sw   t3, 0(a0)
    add  t4, t6, t1
    srai t4, t4, 1
    sw   t4, 0(a1)
    sub  t3, t5, t0
    srai t3, t3, 1
    sw   t3, 0(a2)
    sub  t4, t6, t1
    srai t4, t4, 1
    sw   t4, 0(a3)
    addi s11, s11, 1
    bltu s11, s6, j_loop
    add  s7, s7, s5
    li   t0, N
    bltu s7, t0, grp_loop
    slli s5, s5, 1
    srli a7, a7, 1
    li   t0, N
    ble  s5, t0, stage_loop
    ret

.data
irq_save: .space 16
fill_ptr: .word 0
fill_cnt: .word 0
buf0:     .space {nb}
buf1:     .space {nb}
im_buf:   .space {nb}
rev_tbl:  .space {nb}
wr_tbl:   .space {hb}
wi_tbl:   .space {hb}
"#,
        prelude = programs::PRELUDE,
        n = N,
        windows = WINDOWS,
        nb = N * 4,
        hb = N / 2 * 4,
    )
}

/// The two measurement legs of the study; each runs on its own fleet
/// worker with a private platform.
#[derive(Clone, Copy)]
enum Leg {
    /// IRQ-driven acquisition overlapped with foreground FFTs.
    Overlapped,
    /// Standalone FFT run, measuring the pure processing cost for the
    /// sequential acquire-then-process bound.
    FftBaseline,
}

enum LegOut {
    Overlapped {
        total_s: f64,
        total_mj: f64,
        avg_mw: f64,
        /// (transition count, rendered VCD) of the power-domain trace.
        vcd: Option<(usize, String)>,
    },
    FftCycles(u64),
}

fn main() -> anyhow::Result<()> {
    let cfg = PlatformConfig::default();
    // shared inputs: FFT tables (injected by the CS, like the Fig 5 FFT
    // runs) and the acquired biosignal
    let (wr, wi) = refimpl::twiddles_q15(N);
    let rev: Vec<i32> = refimpl::bit_reverse_indices(N).iter().map(|&x| x as i32).collect();
    let sig = signals::biosignal(0xD0A1, N * WINDOWS, RATE_HZ);

    println!("running {WINDOWS} windows of {N} samples at {RATE_HZ} Hz, overlapped...");
    // both legs are independent platforms -> run them as a 2-point fleet
    // sweep (the overlapped run dominates; the baseline rides along on a
    // second worker)
    let legs = vec![Leg::Overlapped, Leg::FftBaseline];
    let outs = Fleet::auto().run_sweep(&cfg, 0xD0A1, legs, |cfg, leg, _seed| {
        match leg {
            Leg::Overlapped => {
                let mut p = Platform::new(cfg.clone());
                p.dbg.soc.perf.enable_trace(); // power-state VCD of the pipeline
                let prog = p.dbg.load_source(&dual_phase_program())?;
                p.dbg.write_i32_slice(prog.symbol("wr_tbl")?, &wr)?;
                p.dbg.write_i32_slice(prog.symbol("wi_tbl")?, &wi)?;
                p.dbg.write_i32_slice(prog.symbol("rev_tbl")?, &rev)?;
                p.start_adc(sig.samples.clone(), RATE_HZ);
                p.run_app(1 << 36)?;
                assert!(!p.dbg.soc.bus.spi_adc.underrun(), "overlap must not starve acquisition");

                // validate: the final (in-place) FFT of the last window
                // must match the oracle applied to the captured input
                let last_buf = if WINDOWS % 2 == 1 { "buf0" } else { "buf1" };
                let got = p.dbg.read_i32_slice(prog.symbol(last_buf)?, N)?;
                let mut want_re: Vec<i32> = sig.samples[(WINDOWS - 1) * N..].to_vec();
                let mut want_im = vec![0i32; N];
                refimpl::fft_q15(&mut want_re, &mut want_im);
                assert_eq!(got, want_re, "in-place FFT of the last window");

                let snap = p.perf_snapshot();
                let r = EnergyModel::femu().estimate(&snap);
                let vcd = p
                    .dbg
                    .soc
                    .perf
                    .trace()
                    .map(|t| (t.len(), t.to_vcd(cfg.soc.freq_hz, p.dbg.soc.now)));
                Ok(vec![LegOut::Overlapped {
                    total_s: p.dbg.soc.secs(p.dbg.soc.now),
                    total_mj: r.total_mj,
                    avg_mw: r.avg_power_mw(),
                    vcd,
                }])
            }
            Leg::FftBaseline => {
                let mut q = Platform::new(cfg.clone());
                let fprog = q.dbg.load_source(&programs::fft_cpu(N))?;
                q.dbg.write_i32_slice(fprog.symbol("re_buf")?, &sig.samples[..N])?;
                q.dbg.write_i32_slice(fprog.symbol("rev_tbl")?, &rev)?;
                q.dbg.write_i32_slice(fprog.symbol("wr_tbl")?, &wr)?;
                q.dbg.write_i32_slice(fprog.symbol("wi_tbl")?, &wi)?;
                q.run_app(1 << 32)?;
                Ok(vec![LegOut::FftCycles(q.perf_window_snapshot().unwrap().cycles)])
            }
        }
    })?;

    // unpack in leg order (fleet aggregation preserves it)
    let (total_s, total_mj, avg_mw, vcd) = match &outs[0] {
        LegOut::Overlapped { total_s, total_mj, avg_mw, vcd } => {
            (*total_s, *total_mj, *avg_mw, vcd.as_ref())
        }
        _ => unreachable!("leg order"),
    };
    let fft_cycles = match outs[1] {
        LegOut::FftCycles(c) => c,
        _ => unreachable!("leg order"),
    };
    println!("last-window FFT validated against the oracle");

    // timing: total vs the sequential structure
    let acq_s = WINDOWS as f64 * N as f64 / RATE_HZ;
    let proc_s = WINDOWS as f64 * fft_cycles as f64 / cfg.soc.freq_hz as f64;
    let sequential_s = acq_s + proc_s;
    println!("overlapped total : {total_s:.4} s");
    println!("sequential bound : {sequential_s:.4} s (acquire {acq_s:.4} + process {proc_s:.4})");
    println!(
        "overlap hides    : {:.1}% of processing time",
        100.0 * (sequential_s - total_s) / proc_s
    );
    assert!(total_s < sequential_s, "overlap must beat sequential");

    // energy + VCD
    println!("energy: {total_mj:.4} mJ ({avg_mw:.3} mW avg)");
    if let Some((transitions, vcd)) = vcd {
        let path = std::env::temp_dir().join("femu_dual_phase.vcd");
        std::fs::write(&path, vcd)?;
        println!("power-domain waveform: {} ({} transitions)", path.display(), transitions);
    }
    println!("dual_phase OK");
    Ok(())
}
