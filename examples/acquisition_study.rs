//! §V-A acquisition characterization (Fig 4) as a library example:
//! sweep the sampling frequency on the experiment fleet and print the
//! active/sleep split of the acquisition window for both platform
//! calibrations.
//!
//! ```sh
//! cargo run --release --example acquisition_study
//! ```

use femu::config::PlatformConfig;
use femu::coordinator::{experiments, Fleet};

fn main() -> anyhow::Result<()> {
    let cfg = PlatformConfig::default();
    let fleet = Fleet::auto();
    // Short window: the split fractions are window-invariant; the CLI
    // (`femu sweep-acquisition`) runs the paper's full 5 s window.
    let window_s = 0.25;
    println!(
        "acquisition window: {window_s} s (fractions are window-invariant), \
         {} fleet worker(s)",
        fleet.workers()
    );
    println!(
        "{:>10} {:>12} {:>10} {:>10} {:>10}",
        "f_s (Hz)", "platform", "active %", "sleep %", "energy mJ"
    );
    let mut low_active = None;
    let mut high_active = None;
    for p in experiments::fig4_sweep(&fleet, &cfg, window_s, 7)? {
        let active_pct = 100.0 * p.active_s / p.total_s;
        println!(
            "{:>10} {:>12} {:>9.2}% {:>9.2}% {:>10.4}",
            p.sample_rate_hz,
            if p.model == "femu" { "FEMU" } else { "chip" },
            active_pct,
            100.0 - active_pct,
            p.total_mj,
        );
        if p.model == "femu" && p.sample_rate_hz == 100.0 {
            low_active = Some(active_pct);
        }
        if p.model == "femu" && p.sample_rate_hz == 100_000.0 {
            high_active = Some(active_pct);
        }
    }
    // The paper's qualitative claim: sleep-dominated at low rates
    // (<1% active), active-dominated at 100 kHz (>70%).
    let low = low_active.unwrap();
    let high = high_active.unwrap();
    assert!(low < 1.0, "100 Hz active share should be <1%, got {low:.2}%");
    assert!(high > 70.0, "100 kHz active share should be >70%, got {high:.2}%");
    println!("\nacquisition_study OK (100 Hz: {low:.2}% active, 100 kHz: {high:.1}% active)");
    Ok(())
}
