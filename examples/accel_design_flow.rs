//! The paper's §III-B design cycle, end to end, for the MM kernel:
//!
//! 1. run the application CPU-only and profile it (baseline),
//! 2. identify the hot kernel (the matmul loop),
//! 3-5. validate a *virtualized* accelerator model (the AOT Pallas
//!      artifact via PJRT) against the CPU baseline,
//! 6-7. switch to the *RTL-stage* accelerator (the CGRA emulator),
//!      measure performance + energy, and compare with the baseline.
//!
//! ```sh
//! make artifacts && cargo run --release --example accel_design_flow
//! ```

use femu::config::PlatformConfig;
use femu::coordinator::{experiments, Platform};
use femu::runtime::{Runtime, TensorI32};
use femu::util::Rng;
use femu::workloads::{programs, reference as refimpl};

fn main() -> anyhow::Result<()> {
    let cfg = PlatformConfig::default();
    let (m, k, n) = (121usize, 16usize, 4usize);
    let mut rng = Rng::new(0xDE51);
    let a = rng.vec_i32(m * k, -4096, 4096);
    let b = rng.vec_i32(k * n, -4096, 4096);
    let want = refimpl::matmul_i32(&a, &b, m, k, n);

    // ---- step 1-2: CPU-only baseline profile ---------------------------
    println!("[step 1] CPU-only baseline");
    let mut p = Platform::new(cfg.clone());
    let prog = p.dbg.load_source(&programs::mm_cpu(m, k, n))?;
    p.dbg.write_i32_slice(prog.symbol("a_buf")?, &a)?;
    p.dbg.write_i32_slice(prog.symbol("b_buf")?, &b)?;
    p.run_app(1 << 32)?;
    let got = p.dbg.read_i32_slice(prog.symbol("c_buf")?, m * n)?;
    assert_eq!(got, want, "CPU baseline must match the oracle");
    let window = p.perf_window_snapshot().unwrap().clone();
    let cpu_cycles = window.cycles;
    let cpu_energy = cfg.energy.estimate(&window).total_mj;
    println!("  kernel window: {cpu_cycles} cycles, {:.3} uJ", cpu_energy * 1e3);
    println!("[step 2] hot kernel identified: the MM loop (the full window)");

    // ---- steps 3-5: virtualized accelerator model ----------------------
    println!("[steps 3-5] virtualized accelerator model (PJRT artifact)");
    let rt = Runtime::load("artifacts")?;
    let out = rt.execute(
        "matmul",
        &[TensorI32::new(vec![m, k], a.clone())?, TensorI32::new(vec![k, n], b.clone())?],
    )?;
    let virt_ok = out[0].data() == want.as_slice();
    println!("  virtualized model matches CPU baseline: {virt_ok}");
    assert!(virt_ok);

    // ---- steps 6-7: RTL-stage accelerator (CGRA) ------------------------
    println!("[steps 6-7] RTL-stage accelerator (CGRA emulator)");
    let mut p = Platform::new(cfg.clone());
    let prog = p.dbg.load_source(&programs::mm_cgra(m, k, n))?;
    p.dbg.write_i32_slice(prog.symbol("a_buf")?, &a)?;
    p.dbg.write_i32_slice(prog.symbol("b_buf")?, &b)?;
    p.run_app(1 << 32)?;
    let got = p.dbg.read_i32_slice(prog.symbol("c_buf")?, m * n)?;
    assert_eq!(got, want, "CGRA result must match the oracle");
    let window = p.perf_window_snapshot().unwrap().clone();
    let cgra_cycles = window.cycles;
    let cgra_energy = cfg.energy.estimate(&window).total_mj;
    println!("  kernel window: {cgra_cycles} cycles, {:.3} uJ", cgra_energy * 1e3);
    let run = p.dbg.soc.stats.cgra_run;
    println!(
        "  CGRA internals: {} contexts, {} mem-stall cycles, {} config cycles",
        run.contexts, run.mem_stalls, run.config_cycles
    );

    // ---- comparison ------------------------------------------------------
    println!("\n== design-cycle outcome ==");
    println!("  speedup: {:.2}x", cpu_cycles as f64 / cgra_cycles as f64);
    println!("  energy reduction: {:.2}x", cpu_energy / cgra_energy);

    // the same grid is available as a one-call experiment driver:
    let points =
        experiments::fig5_run(&cfg, experiments::Fig5Kernel::Mm, experiments::Fig5Impl::Cgra, 1)?;
    assert!(points.iter().all(|pt| pt.validated));
    println!("accel_design_flow OK");
    Ok(())
}
