//! Remote control: drive the platform through the TCP control server —
//! the paper's §IV-E "user interface" flow (Python-class-over-Jupyter in
//! the original; JSON-line protocol here).
//!
//! ```sh
//! cargo run --release --example remote_control
//! ```

use femu::config::PlatformConfig;
use femu::coordinator::Platform;
use femu::server::{Client, Server};
use femu::util::Json;

fn main() -> anyhow::Result<()> {
    // spawn an in-process server on an ephemeral port
    let platform = Platform::new(PlatformConfig::default());
    let server = Server::spawn(platform, "127.0.0.1:0")?;
    println!("control server at {}", server.addr());
    let mut client = Client::connect(server.addr())?;

    // ping
    let pong = client.call(Json::obj(vec![("cmd", Json::from("ping"))]))?;
    println!("ping -> {pong}");

    // load a program remotely
    let src = r#"
        .equ UART, 0x20000000
        _start:
            la  t0, vec
            li  t1, 4
            li  t2, 0
        loop:
            lw  t3, 0(t0)
            add t2, t2, t3
            addi t0, t0, 4
            addi t1, t1, -1
            bnez t1, loop
            la  t4, result
            sw  t2, 0(t4)
            li  t5, UART
            li  t6, 33        # '!'
            sw  t6, 0(t5)
            ebreak
        .data
        vec:    .space 16
        result: .word 0
    "#;
    let loaded = client.call(Json::obj(vec![
        ("cmd", Json::from("load_asm")),
        ("source", Json::from(src)),
    ]))?;
    let vec_addr = loaded.get("symbols")?.get("vec")?.as_i64()?;
    let res_addr = loaded.get("symbols")?.get("result")?.as_i64()?;
    println!("loaded: vec at {vec_addr:#x}, result at {res_addr:#x}");

    // inject operands remotely
    client.call(Json::obj(vec![
        ("cmd", Json::from("write_mem")),
        ("addr", Json::from(vec_addr)),
        ("values", Json::arr_i32(&[10, 20, 30, -18])),
    ]))?;

    // run
    let run = client.call(Json::obj(vec![("cmd", Json::from("run"))]))?;
    println!("run -> exit={}", run.str_field("exit")?);
    assert_eq!(run.str_field("exit")?, "halted");

    // read the result back
    let mem = client.call(Json::obj(vec![
        ("cmd", Json::from("read_mem")),
        ("addr", Json::from(res_addr)),
        ("n", Json::from(1i64)),
    ]))?;
    let result = mem.as_arr()?[0].as_i64()?;
    println!("result = {result}");
    assert_eq!(result, 42);

    // uart + perf + energy over the wire
    let uart = client.call(Json::obj(vec![("cmd", Json::from("uart"))]))?;
    println!("uart -> {uart}");
    let perf = client.call(Json::obj(vec![("cmd", Json::from("perf"))]))?;
    println!("cycles -> {}", perf.get("cycles")?.as_i64()?);
    let energy = client.call(Json::obj(vec![
        ("cmd", Json::from("energy")),
        ("model", Json::from("heepocrates")),
    ]))?;
    println!(
        "energy -> {:.6} mJ over {:.6} s",
        energy.get("total_mj")?.as_f64()?,
        energy.get("seconds")?.as_f64()?
    );

    server.shutdown();
    println!("remote_control OK");
    Ok(())
}
