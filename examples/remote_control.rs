//! Remote control: drive the platform through the TCP control server —
//! the paper's §IV-E "user interface" flow (Python-class-over-Jupyter in
//! the original; a session-oriented JSON-line protocol here).
//!
//! Exercises the full wire surface: the versioned hello banner,
//! session-less back-compat commands, `session.open` with named and
//! inline configs, concurrent per-session runs, `batch` pipelining,
//! `session.fork` + `snapshot.save`/`snapshot.restore`, a server-side
//! experiment sweep, and graceful shutdown.
//!
//! ```sh
//! cargo run --release --example remote_control
//! ```

use femu::config::PlatformConfig;
use femu::coordinator::Platform;
use femu::server::{Client, Server, ServerOptions};
use femu::util::Json;

fn main() -> anyhow::Result<()> {
    // spawn an in-process server on an ephemeral port, with one extra
    // named config a client can instantiate
    let chip = PlatformConfig::parse("name = \"chip-32mhz\"\nfreq_hz = 32_000_000")?;
    let opts = ServerOptions {
        max_sessions: 8,
        workers: 4,
        named_configs: vec![("chip-32mhz".into(), chip)],
        ..ServerOptions::default()
    };
    let platform = Platform::new(PlatformConfig::default());
    let server = Server::spawn_with(platform, "127.0.0.1:0", opts)?;
    println!("control server at {}", server.addr());
    // connect with a timeout (a hung server would error, not block) and
    // assert on the versioned hello banner
    let mut client =
        Client::connect_with_timeout(server.addr(), std::time::Duration::from_secs(30))?;
    println!("server hello -> {}", client.hello());
    assert_eq!(client.hello().str_field("hello")?, "femu-control-server");
    assert_eq!(
        client.hello().get("proto")?.as_i64()?,
        femu::server::PROTO_VERSION as i64
    );

    // session-less ping still works (targets the default session 0)
    let pong = client.call(Json::obj(vec![("cmd", Json::from("ping"))]))?;
    println!("ping -> {pong}");

    // open a private session per "user": one on the default config, one
    // on the named chip config
    let mine = client.open_session(Json::Null)?;
    let chip_session =
        client.open_session(Json::obj(vec![("config_name", Json::from("chip-32mhz"))]))?;
    println!("sessions: mine={mine}, chip={chip_session}");

    // load a program into MY session
    let src = r#"
        .equ UART, 0x20000000
        _start:
            la  t0, vec
            li  t1, 4
            li  t2, 0
        loop:
            lw  t3, 0(t0)
            add t2, t2, t3
            addi t0, t0, 4
            addi t1, t1, -1
            bnez t1, loop
            la  t4, result
            sw  t2, 0(t4)
            li  t5, UART
            li  t6, 33        # '!'
            sw  t6, 0(t5)
            ebreak
        .data
        vec:    .space 16
        result: .word 0
    "#;
    let loaded = client.call_on(
        mine,
        Json::obj(vec![("cmd", Json::from("load_asm")), ("source", Json::from(src))]),
    )?;
    let vec_addr = loaded.get("symbols")?.get("vec")?.as_i64()?;
    let res_addr = loaded.get("symbols")?.get("result")?.as_i64()?;
    println!("loaded: vec at {vec_addr:#x}, result at {res_addr:#x}");

    // the chip session runs its own guest — its state is invisible to mine
    client.call_on(
        chip_session,
        Json::obj(vec![
            ("cmd", Json::from("load_asm")),
            ("source", Json::from("_start: li a0, 5\nebreak")),
        ]),
    )?;
    let chip_run =
        client.call_on(chip_session, Json::obj(vec![("cmd", Json::from("run"))]))?;
    println!("chip session run -> exit={}", chip_run.str_field("exit")?);

    // pipeline inject + run + readback against MY session in ONE round trip
    let batch = client.batch_on(
        mine,
        vec![
            Json::obj(vec![
                ("cmd", Json::from("write_mem")),
                ("addr", Json::from(vec_addr)),
                ("values", Json::arr_i32(&[10, 20, 30, -18])),
            ]),
            Json::obj(vec![("cmd", Json::from("run"))]),
            Json::obj(vec![
                ("cmd", Json::from("read_mem")),
                ("addr", Json::from(res_addr)),
                ("n", Json::from(1i64)),
            ]),
            Json::obj(vec![("cmd", Json::from("uart"))]),
        ],
    )?;
    assert_eq!(batch.get("completed")?.as_i64()?, 4);
    let results = batch.get("results")?.as_arr()?.to_vec();
    let run = results[1].get("result")?;
    println!("batched run -> exit={}", run.str_field("exit")?);
    assert_eq!(run.str_field("exit")?, "halted");
    let result = results[2].get("result")?.as_arr()?[0].as_i64()?;
    println!("batched result = {result}");
    assert_eq!(result, 42);
    println!("batched uart -> {}", results[3].get("result")?.as_str()?);

    // fork the warmed session: the clone starts from MY session's state
    // (program + memory + counters) and diverges independently
    let forked = client.call(Json::obj(vec![
        ("cmd", Json::from("session.fork")),
        ("session", Json::from(mine as i64)),
    ]))?;
    let fork_id = forked.get("session")?.as_i64()? as u64;
    println!(
        "session.fork -> session {fork_id} ({}) at cycle {}",
        forked.str_field("config")?,
        forked.get("cycles")?.as_i64()?
    );
    let fork_result = client.call_on(
        fork_id,
        Json::obj(vec![
            ("cmd", Json::from("read_mem")),
            ("addr", Json::from(res_addr)),
            ("n", Json::from(1i64)),
        ]),
    )?;
    assert_eq!(fork_result.as_arr()?[0].as_i64()?, 42); // warmed state travelled

    // snapshot the fork over the wire, scribble on it, restore it back
    let saved = client.call_on(fork_id, Json::obj(vec![("cmd", Json::from("snapshot.save"))]))?;
    println!("snapshot.save -> {} bytes (hex on the wire)", saved.get("bytes")?.as_i64()?);
    client.call_on(
        fork_id,
        Json::obj(vec![
            ("cmd", Json::from("write_mem")),
            ("addr", Json::from(res_addr)),
            ("values", Json::arr_i32(&[-7])),
        ]),
    )?;
    client.call_on(
        fork_id,
        Json::obj(vec![
            ("cmd", Json::from("snapshot.restore")),
            ("snapshot", Json::Str(saved.str_field("snapshot")?.to_string())),
        ]),
    )?;
    let restored = client.call_on(
        fork_id,
        Json::obj(vec![
            ("cmd", Json::from("read_mem")),
            ("addr", Json::from(res_addr)),
            ("n", Json::from(1i64)),
        ]),
    )?;
    assert_eq!(restored.as_arr()?[0].as_i64()?, 42); // scribble undone
    client.close_session(fork_id)?;

    // perf + energy over the wire, against my session
    let perf = client.call_on(mine, Json::obj(vec![("cmd", Json::from("perf"))]))?;
    println!("cycles -> {}", perf.get("cycles")?.as_i64()?);
    let energy = client.call_on(
        mine,
        Json::obj(vec![("cmd", Json::from("energy")), ("model", Json::from("heepocrates"))]),
    )?;
    println!(
        "energy -> {:.6} mJ over {:.6} s",
        energy.get("total_mj")?.as_f64()?,
        energy.get("seconds")?.as_f64()?
    );

    // a server-side experiment: the Fig 4 sweep sharded across the
    // server's fleet (tiny window to keep the smoke run fast)
    let sweep = client.call(Json::obj(vec![
        ("cmd", Json::from("sweep_acquisition")),
        ("window_s", Json::Num(0.02)),
    ]))?;
    println!(
        "sweep_acquisition -> {} points over the wire",
        sweep.get("points")?.as_arr()?.len()
    );

    // who's here?
    let listed = client.call(Json::obj(vec![("cmd", Json::from("session.list"))]))?;
    println!("sessions -> {listed}");

    client.close_session(chip_session)?;
    client.close_session(mine)?;
    server.shutdown();
    println!("remote_control OK");
    Ok(())
}
